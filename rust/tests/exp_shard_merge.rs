//! Shard/merge determinism gate (library level): for every sweep, running
//! the manifest in shard slices — each against a freshly-built snapshot,
//! with records round-tripped through the JSONL format — and merging must
//! render **byte-identical** artifacts to the single-process sweep. This
//! is the local counterpart of CI's 3-way shard-matrix + merge fan-in job
//! (which additionally proves it across real processes; so does
//! `tests/cli_shard.rs` for a small sweep).

use qep::exp::common::{
    run_cells, run_cells_durable, render_sweep, scan_record_dir, validate_resume, DurableRun,
    RenderCfg,
};
use qep::exp::plan::{manifest, sizes_of, verify_coverage, PlanParams, ShardSpec, SweepId};
use qep::exp::ExpData;
use qep::io::results::{
    read_records, shard_filename, truncate_torn, write_records, CellRecord, RecordAppender,
};
use qep::model::{Model, ModelConfig, Size};
use qep::text::{Corpus, Flavor};
use qep::util::pool::Pool;
use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};

/// A fresh snapshot with a tiny injected model under the `tiny-s` name.
/// Built per "process" (per shard) from the same deterministic inputs —
/// exactly what independent shard processes do with fallback weights.
fn fresh_data() -> ExpData {
    let mut cfg = ModelConfig::new("tiny-s", 16, 2, 2, 32);
    cfg.seq_len = 8;
    let model = Model::random(&cfg, 3);
    let mut models = HashMap::new();
    models.insert(Size::TinyS.name().to_string(), model);
    let mut corpora = HashMap::new();
    for f in Flavor::all() {
        corpora.insert(f, Corpus::generate(f, 24 * 1024, 0));
    }
    ExpData::from_parts(models, corpora)
}

/// Reduced-size plan params: one size, one fig3 bit width, two seeds,
/// one appendix setting. The *shapes* of every sweep survive; only the
/// grid is trimmed so the full matrix stays test-sized.
fn tiny_params() -> PlanParams {
    let mut p = PlanParams::for_sizes(&[Size::TinyS]);
    p.fig3_bits = vec![3];
    p.fig3_seeds = 2;
    p.appendix_settings = vec![qep::quant::QuantConfig::int(3)];
    p
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("qep_shard_merge_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Every persisted artifact in a results dir, name → bytes.
fn dir_bytes(dir: &Path) -> Vec<(String, Vec<u8>)> {
    let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| {
            let p = e.unwrap().path();
            (p.file_name().unwrap().to_string_lossy().into_owned(), std::fs::read(&p).unwrap())
        })
        .collect();
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

fn render_into(sweep: SweepId, params: &PlanParams, records: Vec<CellRecord>, tag: &str) -> PathBuf {
    let cells = manifest(sweep, params).unwrap();
    let map = verify_coverage(&cells, records).unwrap();
    let dir = tmp_dir(tag);
    let rcfg =
        RenderCfg { results_dir: dir.to_string_lossy().into_owned(), stable_timings: true };
    render_sweep(sweep, params, &map, &rcfg).unwrap();
    dir
}

/// The gate: direct run vs sharded runs (fresh snapshot per shard,
/// records through JSONL files, shard files read back in reverse order)
/// must render the same bytes, for every sweep and several shard counts.
#[test]
fn sharded_merge_renders_byte_identical_tables() {
    let params = tiny_params();
    let pool = Pool::new(4);
    // `All` exercises the table12/table3/table4/fig2/fig3/appendix
    // renderers in one pass; ablation-alpha is not part of `all`.
    for sweep in [SweepId::All, SweepId::AblationAlpha] {
        let cells = manifest(sweep, &params).unwrap();
        let direct_data = fresh_data();
        let direct_records = run_cells(&direct_data, &cells, &pool, 0, 1).unwrap();
        let want_dir = render_into(sweep, &params, direct_records, "direct");
        let want = dir_bytes(&want_dir);
        assert!(!want.is_empty());

        let n_shards = if sweep == SweepId::All { 3 } else { 2 };
        let shard_dir = tmp_dir("shards");
        for i in 1..=n_shards {
            let spec = ShardSpec { index: i, count: n_shards };
            let mine = spec.filter(&cells);
            // Fresh snapshot per shard — what an independent process sees.
            let data = fresh_data();
            assert!(sizes_of(&mine).len() <= 1);
            let recs = run_cells(&data, &mine, &pool, i, n_shards).unwrap();
            write_records(&shard_dir.join(shard_filename(sweep.name(), i, n_shards)), &recs)
                .unwrap();
        }
        // Read shard files back newest-name-first to prove order freedom.
        let mut merged = Vec::new();
        for i in (1..=n_shards).rev() {
            merged.extend(
                read_records(&shard_dir.join(shard_filename(sweep.name(), i, n_shards)))
                    .unwrap(),
            );
        }
        let got_dir = render_into(sweep, &params, merged, "merged");
        let got = dir_bytes(&got_dir);
        assert_eq!(
            want.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            got.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            "{sweep:?}: artifact sets differ"
        );
        for ((name, a), (_, b)) in want.iter().zip(got.iter()) {
            assert_eq!(a, b, "{sweep:?}: '{name}' bytes differ between direct and merged");
        }
        for d in [want_dir, got_dir, shard_dir.clone()] {
            std::fs::remove_dir_all(&d).ok();
        }
    }
}

/// Oversharding (more shards than cells) leaves some shards empty; empty
/// record files must merge cleanly and change nothing.
#[test]
fn empty_shards_merge_cleanly() {
    let params = tiny_params();
    let pool = Pool::new(2);
    let cells = manifest(SweepId::Fig2, &params).unwrap();
    assert_eq!(cells.len(), 2);
    let want_dir = {
        let data = fresh_data();
        let recs = run_cells(&data, &cells, &pool, 0, 1).unwrap();
        render_into(SweepId::Fig2, &params, recs, "fig2_direct")
    };
    let n = 7usize;
    let mut merged = Vec::new();
    for i in 1..=n {
        let spec = ShardSpec { index: i, count: n };
        let mine = spec.filter(&cells);
        if i <= 2 {
            assert_eq!(mine.len(), 1);
        } else {
            assert!(mine.is_empty());
        }
        let data = fresh_data();
        merged.extend(run_cells(&data, &mine, &pool, i, n).unwrap());
    }
    let got_dir = render_into(SweepId::Fig2, &params, merged, "fig2_merged");
    assert_eq!(dir_bytes(&want_dir), dir_bytes(&got_dir));
    std::fs::remove_dir_all(&want_dir).ok();
    std::fs::remove_dir_all(&got_dir).ok();
}

/// Records must survive the JSONL round trip bit-exactly — metric drift
/// here would silently break merged-vs-direct byte identity.
#[test]
fn executed_records_round_trip_bit_exactly() {
    let params = tiny_params();
    let pool = Pool::new(2);
    let cells = manifest(SweepId::Table4, &params).unwrap();
    let data = fresh_data();
    let recs = run_cells(&data, &cells, &pool, 2, 5).unwrap();
    let dir = tmp_dir("roundtrip");
    let path = dir.join(shard_filename("table4", 2, 5));
    write_records(&path, &recs).unwrap();
    let back = read_records(&path).unwrap();
    assert_eq!(back.len(), recs.len());
    for (a, b) in recs.iter().zip(back.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.shard, 2);
        assert_eq!(a.n_shards, 5);
        assert_eq!(a.ppl.len(), b.ppl.len());
        for ((ka, va), (kb, vb)) in a.ppl.iter().zip(b.ppl.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(va.to_bits(), vb.to_bits(), "{}: ppl[{ka}]", a.id);
        }
        for ((ka, va), (kb, vb)) in a.acc.iter().zip(b.acc.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(va.to_bits(), vb.to_bits(), "{}: acc[{ka}]", a.id);
        }
        assert_eq!(a.deltas.len(), b.deltas.len());
        for (va, vb) in a.deltas.iter().zip(b.deltas.iter()) {
            assert_eq!(va.to_bits(), vb.to_bits(), "{}: deltas", a.id);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Shard results are independent of *which* shard ran a cell: the same
/// cell executed under two different shard labels produces identical
/// metrics (only the shard bookkeeping differs).
#[test]
fn cell_results_do_not_depend_on_shard_identity() {
    let params = tiny_params();
    let pool = Pool::new(2);
    let cells = manifest(SweepId::Fig3, &params).unwrap();
    let one = &cells[..1];
    let a = run_cells(&fresh_data(), one, &pool, 1, 3).unwrap().remove(0);
    let b = run_cells(&fresh_data(), one, &pool, 3, 7).unwrap().remove(0);
    assert_eq!(a.id, b.id);
    assert_eq!(a.ppl, b.ppl, "metrics depend on shard identity");
    assert_eq!(a.acc, b.acc);
    assert_eq!((a.shard, a.n_shards), (1, 3));
    assert_eq!((b.shard, b.n_shards), (3, 7));
}

/// The durable executor's contract, library level: per-cell fsynced
/// appends produce the same bytes as the whole-file writer, and an
/// interrupted file (complete prefix + torn tail) resumed with the
/// validated skip set finishes byte-identical to never having crashed.
#[test]
fn durable_appends_and_resume_are_byte_identical_to_uninterrupted() {
    let params = tiny_params();
    let pool = Pool::new(2);
    let cells = manifest(SweepId::Table4, &params).unwrap();
    assert!(cells.len() >= 4, "need enough cells to interrupt meaningfully");

    // Reference: plain in-memory run, stabilized, whole-file write.
    let mut reference = run_cells(&fresh_data(), &cells, &pool, 0, 1).unwrap();
    for r in reference.iter_mut() {
        r.stabilize();
    }
    let dir = tmp_dir("durable");
    let want_path = dir.join(shard_filename("table4", 1, 1));
    write_records(&want_path, &reference).unwrap();
    let want_bytes = std::fs::read(&want_path).unwrap();

    // Leg 1: the durable appender, fresh, must reproduce those bytes.
    let durable_dir = tmp_dir("durable_fresh");
    let got_path = durable_dir.join(shard_filename("table4", 1, 1));
    let empty_skip = HashSet::new();
    let new = run_cells_durable(
        &fresh_data(),
        &cells,
        &pool,
        0,
        1,
        DurableRun {
            skip: &empty_skip,
            sink: RecordAppender::open(&got_path).unwrap(),
            stable_timings: true,
        },
    )
    .unwrap();
    assert_eq!(new.len(), cells.len());
    assert_eq!(std::fs::read(&got_path).unwrap(), want_bytes, "durable vs whole-file bytes");

    // Leg 2: interrupt after 3 records (plus a torn fragment), then
    // resume with the validated skip set.
    let resume_dir = tmp_dir("durable_resume");
    let resume_path = resume_dir.join(shard_filename("table4", 1, 1));
    {
        let mut app = RecordAppender::open(&resume_path).unwrap();
        for r in &reference[..3] {
            app.append(r).unwrap();
        }
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&resume_path).unwrap();
        f.write_all(b"{\"id\":\"table4/RT").unwrap();
    }
    let scan = scan_record_dir(&resume_dir).unwrap();
    assert_eq!(scan.records.len(), 3);
    assert_eq!(scan.torn.len(), 1);
    let skip = validate_resume(&cells, &scan).unwrap();
    assert_eq!(skip.len(), 3);
    assert!(truncate_torn(&resume_path).unwrap());
    let new = run_cells_durable(
        &fresh_data(),
        &cells,
        &pool,
        0,
        1,
        DurableRun {
            skip: &skip,
            sink: RecordAppender::open(&resume_path).unwrap(),
            stable_timings: true,
        },
    )
    .unwrap();
    assert_eq!(new.len(), cells.len() - 3, "only the missing cells re-run");
    assert_eq!(
        std::fs::read(&resume_path).unwrap(),
        want_bytes,
        "interrupted + resumed file differs from uninterrupted"
    );

    // The resumed directory merges to the same render as the reference
    // records (closing the loop through verify_coverage).
    let merged = read_records(&resume_path).unwrap();
    let want_dir = render_into(SweepId::Table4, &params, reference, "durable_want");
    let got_dir = render_into(SweepId::Table4, &params, merged, "durable_got");
    assert_eq!(dir_bytes(&want_dir), dir_bytes(&got_dir));

    for d in [dir, durable_dir, resume_dir, want_dir, got_dir] {
        std::fs::remove_dir_all(&d).ok();
    }
}
