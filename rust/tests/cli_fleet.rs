//! The fleet headline gate, across real processes: a live coordinator
//! (`repro exp serve`) feeding three real worker processes (`repro exp
//! work`) over localhost TCP, with one worker SIGKILLed mid-sweep, must
//! produce a record file AND rendered tables **byte-identical** to an
//! uninterrupted unsharded `repro exp` run (`--stable-timings`). Also
//! drives `exp status --connect` against the live coordinator, the
//! non-empty-dir guard, and a no-worker `--resume` pass over the
//! finished directory. CI's fleet-kill-resume job runs the harsher
//! variant (kills the coordinator too); this is the local, always-on
//! counterpart.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Output, Stdio};
use std::time::{Duration, Instant};

const SWEEP: &str = "ablation-alpha"; // 5 fast RTN-only cells under --fast
const RECORD_FILE: &str = "ablation-alpha.shard-1-of-1.jsonl";
const DEADLINE: Duration = Duration::from_secs(300);

fn repro(args: &[&str], cwd: &Path) -> Output {
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .current_dir(cwd)
        .output()
        .expect("repro binary runs")
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn stdout_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("qep_cli_fleet_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn dir_bytes(dir: &Path) -> Vec<(String, Vec<u8>)> {
    let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("read {}: {e}", dir.display()))
        .map(|e| {
            let p = e.unwrap().path();
            (p.file_name().unwrap().to_string_lossy().into_owned(), std::fs::read(&p).unwrap())
        })
        .collect();
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

fn assert_dirs_equal(want: &Path, got: &Path, what: &str) {
    let (w, g) = (dir_bytes(want), dir_bytes(got));
    assert_eq!(
        w.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
        g.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
        "{what}: file sets differ"
    );
    for ((name, a), (_, b)) in w.iter().zip(g.iter()) {
        assert_eq!(a, b, "{what}: '{name}' differs");
    }
}

/// Wait for a child with the shared deadline instead of blocking forever
/// (a hung fleet must fail the test, not the CI job's timeout).
fn wait_with_deadline(child: &mut Child, what: &str) -> std::process::ExitStatus {
    let deadline = Instant::now() + DEADLINE;
    loop {
        if let Some(status) = child.try_wait().expect("try_wait") {
            return status;
        }
        assert!(Instant::now() < deadline, "{what} did not exit within the deadline");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn fleet_with_sigkilled_worker_matches_unsharded_run_byte_for_byte() {
    let work = tmp("e2e");
    let ref_out = work.join("ref_out");
    let fleet_out = work.join("fleet_out");
    let res_ref = work.join("res_ref");
    let res_fleet = work.join("res_fleet");
    let res_resume = work.join("res_resume");
    let s = |p: &PathBuf| p.to_str().unwrap().to_string();

    // --- Reference leg: uninterrupted unsharded durable run, records +
    // renders.
    let out = repro(
        &[
            "exp", SWEEP, "--fast", "--stable-timings", "--out", &s(&ref_out), "--results",
            &s(&res_ref),
        ],
        &work,
    );
    assert!(out.status.success(), "unsharded reference: {}", stderr_of(&out));
    let ref_bytes = std::fs::read(ref_out.join(RECORD_FILE)).unwrap();

    // --- Fleet leg: coordinator in the background. A short lease bounds
    // how long a half-dead connection could stall dispatch (SIGKILLed
    // workers are requeued instantly on connection drop anyway).
    let mut coord = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "exp", "serve", SWEEP, "--fast", "--stable-timings", "--out", &s(&fleet_out),
            "--results", &s(&res_fleet), "--lease-ms", "2000",
        ])
        .current_dir(&work)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn coordinator");

    // The coordinator advertises its OS-assigned port in fleet.addr.
    let addr_file = fleet_out.join("fleet.addr");
    let deadline = Instant::now() + DEADLINE;
    while !addr_file.is_file() {
        assert!(
            coord.try_wait().expect("try_wait").is_none(),
            "coordinator exited before advertising its address"
        );
        assert!(Instant::now() < deadline, "no fleet.addr within the deadline");
        std::thread::sleep(Duration::from_millis(10));
    }
    let addr_arg = s(&addr_file);

    // Live status straight off the state machine, before any worker
    // connects: everything pending, nobody registered.
    let out = repro(&["exp", "status", "--connect", &addr_arg], &work);
    assert!(out.status.success(), "status --connect: {}", stderr_of(&out));
    let st = stdout_of(&out);
    assert!(st.contains("[fleet] 0/"), "fresh coordinator must report 0 done: {st}");
    assert!(st.contains("0 worker(s) connected"), "{st}");

    // --- Three real workers. Byte-identity must hold for any worker
    // count and any thread count, so give them a different --threads
    // than the reference run used.
    let mut workers: Vec<Child> = (0..3)
        .map(|i| {
            Command::new(env!("CARGO_BIN_EXE_repro"))
                .args(["exp", "work", "--connect", &addr_arg, "--threads", "2"])
                .current_dir(&work)
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .unwrap_or_else(|e| panic!("spawn worker {i}: {e}"))
        })
        .collect();

    // SIGKILL worker 0 the moment the first record durably lands — no
    // cleanup handlers run, the coordinator sees only a dropped
    // connection and must requeue that worker's cells.
    let record_path = fleet_out.join(RECORD_FILE);
    let deadline = Instant::now() + DEADLINE;
    loop {
        let first_record_landed =
            std::fs::read(&record_path).map(|b| b.contains(&b'\n')).unwrap_or(false);
        let coord_exited = coord.try_wait().expect("try_wait").is_some();
        if first_record_landed || coord_exited {
            break;
        }
        assert!(Instant::now() < deadline, "no record landed within the deadline");
        std::thread::sleep(Duration::from_millis(10));
    }
    workers[0].kill().ok();
    workers[0].wait().expect("wait for killed worker");

    // --- Run to completion: the coordinator exits once every cell is
    // durably recorded and rendered; the surviving workers exit cleanly
    // on NoWork{done}.
    let coord_status = wait_with_deadline(&mut coord, "coordinator");
    for (i, w) in workers.iter_mut().enumerate().skip(1) {
        let st = wait_with_deadline(w, "worker");
        assert!(st.success(), "surviving worker {i} exited with {st}");
    }
    let coord_out = coord.wait_with_output().expect("coordinator output");
    assert!(
        coord_status.success(),
        "coordinator failed: {}",
        String::from_utf8_lossy(&coord_out.stderr)
    );
    assert!(
        !addr_file.exists(),
        "fleet.addr must be removed once the coordinator exits"
    );

    // --- The headline asserts: record file AND renders byte-identical
    // to the uninterrupted unsharded run, SIGKILL and all.
    assert_eq!(
        std::fs::read(&record_path).unwrap(),
        ref_bytes,
        "fleet record file differs from the uninterrupted unsharded run"
    );
    assert_dirs_equal(&res_ref, &res_fleet, "fleet renders vs uninterrupted unsharded");

    // --- Guard: a fresh serve into the now-populated dir must refuse,
    // pointing at --resume (same contract as local --out runs).
    let out = repro(
        &["exp", "serve", SWEEP, "--fast", "--stable-timings", "--out", &s(&fleet_out)],
        &work,
    );
    assert!(!out.status.success(), "fresh serve into non-empty dir must fail");
    assert!(stderr_of(&out).contains("--resume"), "{}", stderr_of(&out));

    // --- Coordinator restart over the finished dir: nothing to
    // dispatch, so it needs no workers, exits immediately, and renders
    // the same bytes again.
    let out = repro(
        &[
            "exp", "serve", SWEEP, "--fast", "--stable-timings", "--out", &s(&fleet_out),
            "--resume", "--results", &s(&res_resume),
        ],
        &work,
    );
    assert!(out.status.success(), "serve --resume over finished dir: {}", stderr_of(&out));
    assert_eq!(
        std::fs::read(&record_path).unwrap(),
        ref_bytes,
        "no-op resume must not change the record file"
    );
    assert_dirs_equal(&res_ref, &res_resume, "resumed-coordinator renders vs reference");

    std::fs::remove_dir_all(&work).ok();
}

/// A worker pointed at a dead address fails fast with a useful error —
/// no silent hang (the connect loop gives up after its timeout).
#[test]
fn worker_fails_loudly_when_no_coordinator_listens() {
    let work = tmp("noconn");
    // Reserve a port, then close it so nothing listens there.
    let addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let out = repro(&["exp", "work", "--connect", &addr], &work);
    assert!(!out.status.success(), "worker must fail with nothing listening");
    let err = stderr_of(&out);
    assert!(err.contains(&addr) || err.contains("connect"), "unhelpful error: {err}");
    std::fs::remove_dir_all(&work).ok();
}
