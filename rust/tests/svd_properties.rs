//! Property-test suite gating the deterministic SVD kernel
//! (`qep::linalg::svd`) — the numerical workhorse behind the low-rank
//! quantization-error adjuncts (LQER/QERA).
//!
//! Properties under test:
//! * factor orthogonality: `UᵀU = I` and `Vᵀ·V = I` to tolerance on the
//!   non-null directions;
//! * singular values are non-negative and sorted non-increasing;
//! * truncated reconstruction error is monotone non-increasing in rank;
//! * degenerate shapes behave: rank-deficient inputs produce (near-)zero
//!   trailing singular values with zero factor columns, `1×n` / `n×1` /
//!   zero matrices factor exactly;
//! * **bit-identity**: both engines (full Jacobi and the seeded
//!   randomized range-finder) return byte-for-byte identical factors for
//!   every thread count and every rotation block size — the repo-wide
//!   determinism contract the `.qtz` adjunct sections inherit.

use qep::linalg::{matmul, svd_rank_with, svd_with, svd_with_block, Mat, Svd};
use qep::util::pool::Pool;
use qep::util::rng::Rng;

fn randn(m: usize, n: usize, seed: u64) -> Mat {
    Mat::randn(m, n, 1.0, &mut Rng::new(seed))
}

/// Max |G − I| entry of the Gram matrix of `u`'s columns, restricted to
/// columns with a non-zero singular value (zero triplets are zero
/// vectors by contract, checked separately).
fn u_gram_deviation(f: &Svd) -> f64 {
    let r = f.rank();
    let mut worst = 0.0f64;
    for a in 0..r {
        for b in 0..r {
            if f.s[a] == 0.0 || f.s[b] == 0.0 {
                continue;
            }
            let dot: f64 = (0..f.u.rows)
                .map(|i| f.u.at(i, a) as f64 * f.u.at(i, b) as f64)
                .sum();
            let want = if a == b { 1.0 } else { 0.0 };
            worst = worst.max((dot - want).abs());
        }
    }
    worst
}

/// Same for `vt`'s rows.
fn v_gram_deviation(f: &Svd) -> f64 {
    let r = f.rank();
    let mut worst = 0.0f64;
    for a in 0..r {
        for b in 0..r {
            if f.s[a] == 0.0 || f.s[b] == 0.0 {
                continue;
            }
            let dot: f64 = f
                .vt
                .row(a)
                .iter()
                .zip(f.vt.row(b))
                .map(|(&x, &y)| x as f64 * y as f64)
                .sum();
            let want = if a == b { 1.0 } else { 0.0 };
            worst = worst.max((dot - want).abs());
        }
    }
    worst
}

#[test]
fn factors_are_orthonormal_and_values_sorted() {
    for (m, n, seed) in [(24usize, 24usize, 1u64), (40, 17, 2), (17, 40, 3)] {
        let a = randn(m, n, seed);
        let f = svd_with(&a, &Pool::serial());
        assert_eq!(f.rank(), m.min(n));
        assert!(u_gram_deviation(&f) < 1e-4, "{m}x{n}: UᵀU deviates");
        assert!(v_gram_deviation(&f) < 1e-4, "{m}x{n}: V rows deviate");
        for &s in &f.s {
            assert!(s >= 0.0, "negative singular value in {:?}", f.s);
        }
        for w in f.s.windows(2) {
            assert!(w[0] >= w[1], "unsorted singular values: {:?}", f.s);
        }
    }
}

#[test]
fn reconstruction_error_is_monotone_in_rank() {
    let a = randn(30, 18, 9);
    let full = svd_with(&a, &Pool::serial());
    let mut prev = f64::INFINITY;
    for r in 0..=18 {
        let err = a.sub(&full.clone().truncate(r).reconstruct()).frob();
        assert!(
            err <= prev + 1e-4 * a.frob(),
            "rank {r}: error {err} rose above rank {}'s {prev}",
            r.max(1) - 1
        );
        prev = err;
    }
    // Full rank reconstructs the matrix (f32 storage tolerance).
    assert!(prev < 1e-3 * a.frob(), "full-rank residual {prev}");
}

#[test]
fn rank_deficient_inputs_have_zero_tail() {
    // A = U·V with inner dimension 3: exactly rank 3.
    let a = matmul(&randn(30, 3, 4), &randn(3, 20, 5));
    let f = svd_with(&a, &Pool::serial());
    assert_eq!(f.rank(), 20);
    for t in 3..20 {
        assert!(
            (f.s[t] as f64) < 1e-4 * f.s[0] as f64,
            "trailing value s[{t}]={} should be ~0 (s[0]={})",
            f.s[t],
            f.s[0]
        );
    }
    // Exactly-zero triplets come with exactly-zero U columns.
    for t in 0..20 {
        if f.s[t] == 0.0 {
            assert!((0..30).all(|i| f.u.at(i, t) == 0.0), "non-zero null column {t}");
        }
    }
    assert!(a.sub(&f.reconstruct()).frob() < 1e-3 * a.frob());
}

#[test]
fn degenerate_shapes_factor_exactly() {
    // 1×n: a single row is rank 1 with s[0] = its norm.
    let row = randn(1, 13, 6);
    let f = svd_with(&row, &Pool::serial());
    assert_eq!(f.rank(), 1);
    assert!((f.s[0] as f64 - row.frob()).abs() < 1e-4 * row.frob());
    assert!(row.sub(&f.reconstruct()).frob() < 1e-4 * row.frob());

    // n×1: a single column.
    let col = randn(13, 1, 7);
    let f = svd_with(&col, &Pool::serial());
    assert_eq!(f.rank(), 1);
    assert!((f.s[0] as f64 - col.frob()).abs() < 1e-4 * col.frob());
    assert!(col.sub(&f.reconstruct()).frob() < 1e-4 * col.frob());

    // Zero matrix: all-zero triplets, and rank-0 requests yield empty
    // factors of the right shape.
    let z = Mat::zeros(7, 5);
    let f = svd_with(&z, &Pool::serial());
    assert!(f.s.iter().all(|&s| s == 0.0));
    assert!(f.u.data.iter().all(|&x| x == 0.0));
    assert!(f.vt.data.iter().all(|&x| x == 0.0));
    let r0 = svd_rank_with(&randn(7, 5, 8), 0, 1, &Pool::serial());
    assert_eq!(r0.rank(), 0);
    assert_eq!((r0.u.rows, r0.u.cols), (7, 0));
    assert_eq!((r0.vt.rows, r0.vt.cols), (0, 5));
}

#[test]
fn jacobi_is_bit_identical_across_threads_and_block_sizes() {
    // m >= 64 so the pooled rotation path actually engages for
    // multi-thread pools; tall and wide (transpose path) both covered.
    for (m, n) in [(96usize, 40usize), (40, 96)] {
        let a = randn(m, n, 10);
        let reference = svd_with(&a, &Pool::serial());
        for threads in [1usize, 2, 8] {
            let pool = Pool::new(threads);
            for block in [0usize, 7, 16, 33, 96] {
                let f = svd_with_block(&a, &pool, block);
                assert_eq!(
                    f, reference,
                    "{m}x{n}: threads={threads} block={block} changed bits"
                );
            }
        }
    }
}

#[test]
fn randomized_engine_is_bit_identical_across_thread_counts() {
    // min(m, n) = 120 > 96 and sketch 6+8 = 14 (≪ 60), so this takes the
    // seeded range-finder, whose GEMMs run on the pool.
    let a = randn(220, 120, 11);
    let reference = svd_rank_with(&a, 6, 42, &Pool::serial());
    assert_eq!(reference.rank(), 6);
    for threads in [1usize, 2, 8] {
        let f = svd_rank_with(&a, 6, 42, &Pool::new(threads));
        assert_eq!(f, reference, "threads={threads} changed randomized-SVD bits");
    }
    // Different seeds may sketch differently, but the same seed is a
    // pure function: repeat calls are identical too.
    assert_eq!(svd_rank_with(&a, 6, 42, &Pool::serial()), reference);
}

#[test]
fn truncated_engines_agree_with_the_full_factorization_prefix() {
    // Small problems route the rank path straight to Jacobi: the result
    // must be exactly the truncated full factorization.
    let a = randn(26, 19, 12);
    let full = svd_with(&a, &Pool::serial());
    for r in [1usize, 4, 19, 50] {
        let t = svd_rank_with(&a, r, 77, &Pool::serial());
        let want = full.clone().truncate(r.min(19));
        assert_eq!(t, want, "rank {r} disagrees with the full prefix");
    }
}
