//! `--threads 1` must bypass the persistent workers entirely: every
//! kernel runs inline on the calling thread and no worker thread is ever
//! spawned. This file is the *only* test in its integration-test binary
//! (cargo gives each `tests/*.rs` file its own process), so the
//! process-global "have workers started?" flag is observable without
//! interference from other tests.

use qep::coordinator::{Pipeline, PipelineConfig};
use qep::linalg::{matmul, matmul_serial, spd_solve_with, Mat, Mat64};
use qep::util::pool::{self, Pool};
use qep::util::rng::Rng;

#[test]
fn serial_work_never_starts_the_persistent_workers() {
    // Pin the process-wide default to 1 thread, like `repro --threads 1`.
    pool::set_global_threads(1);
    assert!(!pool::workers_started(), "workers must not exist at startup");

    // Pool-level serial work.
    let pool = Pool::serial();
    let sum = std::sync::atomic::AtomicUsize::new(0);
    pool.run(100, 8, |s, e| {
        sum.fetch_add(e - s, std::sync::atomic::Ordering::Relaxed);
    });
    assert_eq!(sum.load(std::sync::atomic::Ordering::Relaxed), 100);
    assert_eq!(Pool::new(1).par_map(5, |i| i * 2), vec![0, 2, 4, 6, 8]);

    // Kernel-level work through the global (now 1-thread) pool and an
    // explicit serial pool.
    let mut rng = Rng::new(1);
    let a = Mat::randn(64, 96, 1.0, &mut rng);
    let b = Mat::randn(96, 48, 1.0, &mut rng);
    assert_eq!(matmul(&a, &b), matmul_serial(&a, &b));

    let mut h = Mat64::eye(32);
    h.add_diag(3.0);
    let rhs = Mat64::eye(32);
    let x = spd_solve_with(&h, &rhs, &Pool::serial()).unwrap();
    assert!((x.at(0, 0) - 0.25).abs() < 1e-12);

    // A whole single-threaded pipeline run.
    let mut cfg = qep::model::ModelConfig::new("unit", 16, 2, 2, 32);
    cfg.seq_len = 8;
    let model = qep::model::Model::random(&cfg, 1);
    let tokens: Vec<u32> = (0..8 * 16).map(|i| (i % 256) as u32).collect();
    let out = Pipeline::new(PipelineConfig { threads: 1, ..Default::default() })
        .run(&model, &tokens)
        .unwrap();
    out.model.validate().unwrap();

    assert!(
        !pool::workers_started(),
        "threads=1 must never spawn persistent workers"
    );

    // Sanity: an actual parallel dispatch *does* start them (and shutdown
    // joins them again), proving the flag is live in this process.
    pool::set_global_threads(0);
    let _ = Pool::new(2).par_map(4, |i| i);
    assert!(pool::workers_started());
    pool::shutdown();
    assert!(!pool::workers_started());
}
