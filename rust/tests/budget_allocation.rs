//! Integration gates for the Hessian-guided mixed-precision budget
//! allocator (`quant::budget`). The contract under test:
//!
//! - on convex cost curves the greedy marginal-gain allocator and the
//!   exact DP allocator pick the SAME per-layer widths (greedy is
//!   optimal there), and both dominate the uniform floor on the proxy;
//! - infeasible budgets fail loudly, naming the feasible range;
//! - edge budgets (exact grid bounds, single layer, exact ties) resolve
//!   deterministically with the documented lowest-layer-index tie-break;
//! - the allocation a pipeline produces is bit-identical for every
//!   thread count, and the `.qtz` allocation meta round-trips through
//!   save/load byte-identically.

use qep::coordinator::{Pipeline, PipelineConfig, PipelineOutput};
use qep::linalg::Mat;
use qep::model::{Model, ModelConfig};
use qep::quant::budget::{
    allocate, check_feasible, layer_cost, read_allocation_meta, write_allocation_meta, LayerCost,
};
use qep::quant::{Alloc, BitBudget, BudgetSpec, Method, QuantConfig};
use qep::util::rng::Rng;

/// Strictly decreasing, strictly convex curve: each one-bit upgrade
/// buys a strictly smaller gain than the previous one.
fn convex_curve(rng: &mut Rng, len: usize) -> Vec<f64> {
    let mut gains = Vec::with_capacity(len - 1);
    let mut g = rng.range_f64(1.0, 5.0);
    for _ in 0..len - 1 {
        gains.push(g);
        g *= rng.range_f64(0.3, 0.8);
    }
    let mut err = vec![gains.iter().sum::<f64>() + rng.range_f64(0.0, 1.0)];
    for g in gains {
        let last = *err.last().unwrap();
        err.push(last - g);
    }
    err
}

fn budget(s: &str) -> BitBudget {
    BitBudget::parse(s).unwrap()
}

#[test]
fn greedy_and_dp_agree_on_convex_curves() {
    let mut rng = Rng::new(11);
    for trial in 0..20 {
        let n = 2 + rng.below(6);
        let weights = 64 * (1 + rng.below(4));
        let costs: Vec<LayerCost> = (0..n)
            .map(|i| LayerCost {
                name: format!("blocks.{i}.wq"),
                weights,
                err: convex_curve(&mut rng, 5),
            })
            .collect();
        for b in ["2.5", "3.5", "4.2", "5.9"] {
            let greedy = allocate(&costs, budget(b), Alloc::Greedy).unwrap();
            let dp = allocate(&costs, budget(b), Alloc::Dp).unwrap();
            assert_eq!(
                greedy.bits, dp.bits,
                "trial {trial} budget {b}: greedy and DP disagree on a convex instance"
            );
            assert_eq!(greedy.avg_bits, dp.avg_bits, "trial {trial} budget {b}");
            // Budget respected, floor guaranteed, allocated proxy error
            // dominates the uniform floor.
            let bb = budget(b);
            let floor = bb.floor_bits();
            assert!(dp.avg_bits <= bb.decibits() as f64 / 10.0 + 1e-12);
            let mut total_alloc = 0.0;
            let mut total_floor = 0.0;
            for c in &costs {
                let assigned = dp.bits[&c.name];
                assert!(assigned >= floor, "layer below the floor");
                total_alloc += c.err[(assigned - floor) as usize];
                total_floor += c.err[0];
            }
            assert!(
                total_alloc <= total_floor + 1e-12,
                "trial {trial} budget {b}: allocation worse than uniform floor"
            );
        }
    }
}

#[test]
fn infeasible_budgets_name_the_feasible_range() {
    for s in ["1.9", "0.5", "8.1", "9.0"] {
        let err = check_feasible(budget(s)).unwrap_err().to_string();
        assert!(
            err.contains("feasible range is [2.0, 8.0]"),
            "budget {s}: error must name the feasible range, got: {err}"
        );
        // allocate() runs the same gate before any work.
        let costs =
            vec![LayerCost { name: "blocks.0.wq".into(), weights: 64, err: vec![2.0, 1.0] }];
        assert!(allocate(&costs, budget(s), Alloc::Dp).is_err());
    }
    for s in ["2.0", "2.5", "8.0"] {
        check_feasible(budget(s)).unwrap();
    }
}

#[test]
fn grid_bound_budgets_pin_every_layer() {
    let costs: Vec<LayerCost> = (0..3)
        .map(|i| LayerCost {
            name: format!("blocks.{i}.wq"),
            weights: 32,
            err: vec![4.0, 2.0, 1.0, 0.5, 0.25, 0.125, 0.0625],
        })
        .collect();
    for alloc in [Alloc::Greedy, Alloc::Dp] {
        // Integral budget: zero fractional surplus, everyone at the floor.
        let a = allocate(&costs, budget("2.0"), alloc).unwrap();
        assert!(a.bits.values().all(|&b| b == 2), "{}", a.summary());
        assert_eq!(a.avg_bits, 2.0);
        // Top of the grid: the floor IS the ceiling.
        let a = allocate(&costs, budget("8.0"), alloc).unwrap();
        assert!(a.bits.values().all(|&b| b == 8), "{}", a.summary());
        assert_eq!(a.avg_bits, 8.0);
    }
}

#[test]
fn single_layer_fractional_budget_stays_at_the_floor() {
    // One layer cannot split a fractional surplus: a whole-bit upgrade
    // would overshoot the average, so the layer keeps ⌊B⌋ bits.
    let costs = vec![LayerCost {
        name: "blocks.0.wq".into(),
        weights: 256,
        err: vec![3.0, 1.0, 0.1],
    }];
    for alloc in [Alloc::Greedy, Alloc::Dp] {
        let a = allocate(&costs, budget("2.5"), alloc).unwrap();
        assert_eq!(a.bits["blocks.0.wq"], 2, "{}", a.summary());
        assert_eq!(a.avg_bits, 2.0);
    }
}

#[test]
fn exact_ties_upgrade_the_lowest_layer_index() {
    // Two bit-identical layers, capacity for exactly one upgrade. The
    // winner is the lower INDEX in the cost slice — not the
    // lexicographically smaller name.
    let curve = vec![10.0, 4.0, 1.0];
    let costs = vec![
        LayerCost { name: "z.late".into(), weights: 128, err: curve.clone() },
        LayerCost { name: "a.early".into(), weights: 128, err: curve },
    ];
    for alloc in [Alloc::Greedy, Alloc::Dp] {
        let a = allocate(&costs, budget("2.5"), alloc).unwrap();
        assert_eq!(a.bits["z.late"], 3, "{:?}: index 0 must win the tie", alloc);
        assert_eq!(a.bits["a.early"], 2, "{:?}", alloc);
        assert_eq!(a.avg_bits, 2.5);
    }
}

#[test]
fn layer_cost_curves_are_monotone_in_bits() {
    // More bits never increase the Hessian-weighted snap error — the
    // convexity the allocators exploit starts with monotonicity.
    let mut rng = Rng::new(5);
    let w = Mat::randn(8, 32, 1.0, &mut rng);
    let diag: Vec<f64> = (0..32).map(|_| rng.range_f64(0.1, 4.0)).collect();
    let c = layer_cost("blocks.0.wq", &w, &diag, &QuantConfig::int(2), 2, 8);
    assert_eq!(c.weights, 8 * 32);
    assert_eq!(c.err.len(), 7);
    for k in 1..c.err.len() {
        assert!(
            c.err[k] <= c.err[k - 1],
            "err must be non-increasing: err[{k}]={} > err[{}]={}",
            c.err[k],
            k - 1,
            c.err[k - 1]
        );
    }
    assert!(c.err[0] > 0.0, "INT2 snap error should be strictly positive on random weights");
}

fn tiny_budget_run(alloc: Alloc, threads: usize) -> PipelineOutput {
    let mut mcfg = ModelConfig::new("unit", 16, 2, 2, 32);
    mcfg.seq_len = 8;
    let model = Model::random(&mcfg, 1);
    let mut rng = Rng::new(2);
    let tokens: Vec<u32> = (0..8 * 16).map(|_| rng.below(256) as u32).collect();
    let cfg = PipelineConfig {
        quant: QuantConfig::int(7), // superseded by the budget's floor
        method: Method::Rtn,
        bit_budget: Some(BudgetSpec { budget: BitBudget::from_decibits(25), alloc }),
        seed: 42,
        threads,
        ..Default::default()
    };
    Pipeline::new(cfg).run(&model, &tokens).unwrap()
}

#[test]
fn pipeline_allocation_is_bit_identical_across_thread_counts() {
    for alloc in [Alloc::Greedy, Alloc::Dp] {
        let want = tiny_budget_run(alloc, 1);
        let wa = want.allocation.as_ref().unwrap();
        // Floor guarantee: budget 2.5 means every layer is INT2 or INT3.
        assert!(wa.bits.values().all(|&b| b == 2 || b == 3), "{}", wa.summary());
        assert!(wa.avg_bits >= 2.0 && wa.avg_bits <= 2.5, "{}", wa.summary());
        for threads in [2usize, 8] {
            let got = tiny_budget_run(alloc, threads);
            assert_eq!(
                want.allocation, got.allocation,
                "{alloc:?}: allocation differs at threads={threads}"
            );
        }
    }
}

#[test]
fn qtz_allocation_meta_round_trips_byte_identically() {
    let out = tiny_budget_run(Alloc::Dp, 4);
    let alloc = out.allocation.clone().unwrap();
    let dir = std::env::temp_dir();

    // Same model + same allocation → same bytes, twice over.
    let write = |name: &str| -> Vec<u8> {
        let mut tf = out.model.to_tensor_file();
        write_allocation_meta(&mut tf.meta, &alloc);
        let p = dir.join(name);
        tf.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        bytes
    };
    let b1 = write("qep_budget_meta_roundtrip_1.qtz");
    let b2 = write("qep_budget_meta_roundtrip_2.qtz");
    assert!(!b1.is_empty());
    assert_eq!(b1, b2, "allocation meta serialization is not deterministic");

    // Load-side: the meta restores the exact allocation.
    let p = dir.join("qep_budget_meta_roundtrip_3.qtz");
    let mut tf = out.model.to_tensor_file();
    write_allocation_meta(&mut tf.meta, &alloc);
    tf.save(&p).unwrap();
    let loaded = qep::io::TensorFile::load(&p).unwrap();
    std::fs::remove_file(&p).ok();
    let got = read_allocation_meta(&loaded.meta).unwrap().expect("meta must parse back");
    assert_eq!(got, alloc);

    // A plain model file carries no allocation.
    assert!(read_allocation_meta(&out.model.to_tensor_file().meta).unwrap().is_none());
}
