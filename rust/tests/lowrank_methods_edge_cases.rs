//! Edge-case suite for the low-rank quantization-error reconstruction
//! (LQER/QERA) method family, end to end through the pipeline:
//!
//! * rank 0 is *exactly* the no-adjunct pipeline (same model bytes, no
//!   adjunct map, no base-model split);
//! * a rank ≥ min(out, in) adjunct reconstructs the layer residual to
//!   f32 precision, so the effective model returns to the target;
//! * degenerate calibration/weights (dead activation columns, singular
//!   Hessians, all-zero layers) stay finite and produce zero adjuncts
//!   where the residual is zero;
//! * every `bits × method × ±QEP × ±lowrank` combination quantizes and
//!   evaluates to a finite perplexity on a tiny model;
//! * a `.qtz` with an adjunct section is byte-identical across
//!   write → read → write, and evaluation's materialized model equals
//!   the pipeline's effective model.

use qep::coordinator::{Pipeline, PipelineConfig, PipelineOutput};
use qep::eval::perplexity;
use qep::linalg::{Mat, Mat64};
use qep::model::{Model, ModelConfig};
use qep::qep::{
    adjunct_from_residual, load_with_adjuncts, materialize_into_model, save_with_adjuncts,
};
use qep::quant::{Method, QuantConfig};
use qep::util::pool::Pool;
use qep::util::rng::Rng;

fn setup() -> (Model, Vec<u32>) {
    let mut cfg = ModelConfig::new("unit", 16, 2, 2, 32);
    cfg.seq_len = 8;
    let model = Model::random(&cfg, 1);
    let mut rng = Rng::new(2);
    let tokens: Vec<u32> = (0..8 * 16).map(|_| rng.below(256) as u32).collect();
    (model, tokens)
}

fn run(
    model: &Model,
    tokens: &[u32],
    method: Method,
    bits: u32,
    qep_alpha: Option<f32>,
    lowrank_rank: usize,
) -> PipelineOutput {
    let cfg = PipelineConfig {
        quant: QuantConfig::int(bits),
        method,
        qep_alpha,
        lowrank_rank,
        seed: 42,
        ..Default::default()
    };
    Pipeline::new(cfg).run(model, tokens).unwrap()
}

#[test]
fn rank_zero_is_exactly_the_no_adjunct_pipeline() {
    let (model, tokens) = setup();
    let plain = run(&model, &tokens, Method::Gptq, 3, Some(0.5), 0);
    assert!(plain.adjuncts.is_empty(), "rank 0 must produce no adjuncts");
    assert!(plain.base_model.is_none(), "rank 0 must not split a base model");
    // And the model is bit-identical to a run that never heard of the
    // field (rank 0 is the Default) — same serialized bytes.
    let default_cfg = run(&model, &tokens, Method::Gptq, 3, Some(0.5), 0);
    assert_eq!(
        plain.model.to_tensor_file().serialize(),
        default_cfg.model.to_tensor_file().serialize()
    );
}

#[test]
fn full_rank_adjunct_restores_the_layer_targets() {
    let (model, tokens) = setup();
    // Rank far above every layer's min(out, in): clamped per layer, and
    // U·V then reconstructs the whole residual to f32 precision — the
    // effective weights return to the (coarse-grid INT2) targets, i.e.
    // the original weights for a base-method run.
    let out = run(&model, &tokens, Method::Rtn, 2, None, 999);
    assert_eq!(out.adjuncts.len(), 2 * 7);
    for (name, adj) in &out.adjuncts {
        assert_eq!(adj.rank(), 16, "{name}: rank must clamp to min(out, in)");
    }
    for bi in 0..model.blocks.len() {
        for short in ["attn.wq", "attn.wk", "attn.wv", "attn.wo", "mlp.gate", "mlp.up", "mlp.down"]
        {
            let orig = model.blocks[bi].linear(short);
            let eff = out.model.blocks[bi].linear(short);
            let rel = eff.sub(orig).frob() / orig.frob().max(1e-12);
            assert!(rel < 1e-2, "blocks.{bi}.{short}: full-rank residual {rel}");
        }
    }
}

#[test]
fn degenerate_residuals_and_hessians_stay_finite() {
    let pool = Pool::serial();
    // Dead input columns: activations (and thus the Hessian) vanish on
    // coordinates 3..8 — the damped Cholesky must still factor and the
    // adjunct must stay finite.
    let mut rng = Rng::new(4);
    let residual = Mat::randn(12, 10, 0.1, &mut rng);
    let mut h = Mat64::zeros(10, 10);
    for j in 0..10 {
        *h.at_mut(j, j) = if (3..8).contains(&j) { 0.0 } else { 5.0 };
    }
    let adj = adjunct_from_residual(&residual, Some(&h), 3, 1.0, 7, &pool);
    assert_eq!(adj.rank(), 3);
    assert!(adj.u.data.iter().all(|v| v.is_finite()), "U has non-finite entries");
    assert!(adj.v.data.iter().all(|v| v.is_finite()), "V has non-finite entries");
    // All-zero residual: the adjunct is exactly zero (no NaN from
    // normalizing null singular directions).
    let zadj = adjunct_from_residual(&Mat::zeros(8, 6), Some(&h2(6)), 4, 1.0, 1, &pool);
    assert_eq!(zadj.materialize(), Mat::zeros(8, 6));
}

fn h2(n: usize) -> Mat64 {
    let mut h = Mat64::zeros(n, n);
    h.add_diag(1.0);
    h
}

#[test]
fn all_zero_layers_quantize_with_zero_adjuncts() {
    let (mut model, tokens) = setup();
    model.blocks[0].wq = Mat::zeros(16, 16);
    let out = run(&model, &tokens, Method::Rtn, 3, None, 4);
    let adj = &out.adjuncts["blocks.0.attn.wq"];
    // Q(0) = 0 ⇒ zero residual ⇒ zero adjunct; and the effective weight
    // stays exactly zero.
    assert_eq!(adj.materialize(), Mat::zeros(16, 16));
    assert_eq!(out.model.blocks[0].wq, Mat::zeros(16, 16));
    assert!(perplexity(&out.model, &tokens).is_finite());
}

#[test]
fn every_bits_method_qep_lowrank_combo_has_finite_ppl() {
    let (model, tokens) = setup();
    for bits in [2u32, 3, 4] {
        for method in Method::all() {
            for qep_alpha in [None, Some(0.5)] {
                for rank in [0usize, 2] {
                    let label =
                        format!("int{bits} {method:?} qep={qep_alpha:?} rank={rank}");
                    let out = run(&model, &tokens, method, bits, qep_alpha, rank);
                    if rank == 0 {
                        assert!(out.adjuncts.is_empty(), "{label}");
                    } else {
                        assert_eq!(out.adjuncts.len(), 2 * 7, "{label}");
                        assert!(out.adjuncts.values().all(|a| a.rank() == rank), "{label}");
                    }
                    let ppl = perplexity(&out.model, &tokens);
                    assert!(ppl.is_finite() && ppl > 0.0, "{label}: ppl {ppl}");
                }
            }
        }
    }
}

#[test]
fn qtz_with_adjuncts_roundtrips_byte_exact_and_eval_matches_effective() {
    let (model, tokens) = setup();
    let out = run(&model, &tokens, Method::Gptq, 3, Some(0.5), 2);
    let base = out.base_model.as_ref().expect("rank > 0 must keep the base model");

    let dir = std::env::temp_dir();
    let p1 = dir.join("qep_lowrank_roundtrip_1.qtz");
    let p2 = dir.join("qep_lowrank_roundtrip_2.qtz");
    save_with_adjuncts(&p1, base, &out.adjuncts, 2).unwrap();
    let (mut loaded, adjs) = load_with_adjuncts(&p1).unwrap();
    assert_eq!(adjs, out.adjuncts, "adjunct section must round-trip exactly");
    save_with_adjuncts(&p2, &loaded, &adjs, 2).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    let b2 = std::fs::read(&p2).unwrap();
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
    assert!(!b1.is_empty());
    assert_eq!(b1, b2, "write→read→write must be byte-identical");

    // Folding the loaded adjuncts back in reproduces the pipeline's
    // effective model bit-for-bit (install() and materialize share the
    // same fixed-order f64 accumulation).
    materialize_into_model(&mut loaded, &adjs).unwrap();
    assert_eq!(
        loaded.to_tensor_file().serialize(),
        out.model.to_tensor_file().serialize(),
        "eval's materialized model must equal the pipeline's effective model"
    );
}
