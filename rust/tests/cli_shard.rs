//! End-to-end CLI tests through the real `repro` binary: strict flag
//! rejection, and the distributed shard → merge flow across *separate
//! processes* (the strongest local form of the determinism gate — every
//! process rebuilds its own snapshot from scratch).

use qep::exp::PlanCell;
use std::path::{Path, PathBuf};
use std::process::{Command, Output};

fn repro(args: &[&str], cwd: &Path) -> Output {
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .current_dir(cwd)
        .output()
        .expect("repro binary runs")
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn stdout_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("qep_cli_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn unknown_flags_commands_and_ids_are_rejected() {
    let dir = tmp("reject");

    // The classic typo: --shards for --shard. Must fail with a hint, not
    // silently run every cell.
    let out = repro(&["exp", "table4", "--shards", "2/3"], &dir);
    assert!(!out.status.success(), "typo'd flag must fail");
    let err = stderr_of(&out);
    assert!(err.contains("unknown flag '--shards'"), "{err}");
    assert!(err.contains("did you mean '--shard'?"), "{err}");

    let out = repro(&["frobnicate"], &dir);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("unknown command"), "{}", stderr_of(&out));

    let out = repro(&["exp", "bogus"], &dir);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("unknown experiment"), "{}", stderr_of(&out));

    let out = repro(&["quantize", "--model", "tiny-s", "--quiet"], &dir);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("unknown flag '--quiet'"), "{}", stderr_of(&out));

    // --shard needs --out, and the spec is validated.
    let out = repro(&["exp", "fig2", "--fast", "--shard", "1/2"], &dir);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--out"), "{}", stderr_of(&out));
    let out = repro(&["exp", "fig2", "--fast", "--shard", "0/3", "--out", "s"], &dir);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--shard expects i/N"), "{}", stderr_of(&out));
    // Render-only flags are meaningless on a shard run (it never
    // renders) — reject rather than silently ignore. (--stable-timings
    // is NOT render-only anymore: with --out it zeroes record timings.)
    let out = repro(
        &["exp", "fig2", "--fast", "--shard", "1/2", "--out", "s", "--results", "r"],
        &dir,
    );
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("no effect with --shard"), "{}", stderr_of(&out));

    // --resume without --out has nothing to resume from.
    let out = repro(&["exp", "fig2", "--fast", "--resume"], &dir);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--resume requires --out"), "{}", stderr_of(&out));

    // exp status needs the record directory.
    let out = repro(&["exp", "status", "fig2", "--fast"], &dir);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--out required"), "{}", stderr_of(&out));

    // Flags a subcommand never reads are rejected, not silently ignored:
    // merge always collects the full manifest, so --shard is invalid there.
    let out = repro(&["exp", "merge", "all", "--fast", "--shard", "1/3", "--out", "s"], &dir);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("unknown flag '--shard'"), "{}", stderr_of(&out));

    // Merging an empty directory is an error, not an empty render.
    let out = repro(
        &["exp", "merge", "fig2", "--fast", "--out", dir.to_str().unwrap()],
        &dir,
    );
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("no .jsonl record files"), "{}", stderr_of(&out));

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn plan_lists_parseable_cell_ids() {
    let dir = tmp("plan");
    let out = repro(&["exp", "plan", "all", "--fast"], &dir);
    assert!(out.status.success(), "{}", stderr_of(&out));
    let ids: Vec<String> = stdout_of(&out).lines().map(|l| l.to_string()).collect();
    assert!(ids.len() > 20, "expected a full manifest, got {}", ids.len());
    for id in &ids {
        assert!(PlanCell::parse(id).is_some(), "unparseable manifest id '{id}'");
    }
    // A shard slice is a strict subset in manifest order.
    let out = repro(&["exp", "plan", "all", "--fast", "--shard", "2/3"], &dir);
    assert!(out.status.success());
    let slice: Vec<String> = stdout_of(&out).lines().map(|l| l.to_string()).collect();
    assert!(slice.len() < ids.len());
    let mut cursor = 0usize;
    for id in &slice {
        let pos = ids[cursor..].iter().position(|x| x == id).expect("slice id in manifest");
        cursor += pos + 1;
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The cross-process gate on a small sweep: two shard processes + a merge
/// process render the same bytes as one unsharded process, and the
/// cell-level runner (`repro exp cell`) reproduces it a third way.
#[test]
fn shard_merge_across_processes_matches_unsharded_run() {
    let work = tmp("e2e");
    let shards = work.join("shards");
    let res_single = work.join("res_single");
    let res_merged = work.join("res_merged");
    let res_cells = work.join("res_cells");
    let s = |p: &PathBuf| p.to_str().unwrap().to_string();

    // Unsharded reference run.
    let out = repro(
        &["exp", "fig2", "--fast", "--stable-timings", "--results", &s(&res_single)],
        &work,
    );
    assert!(out.status.success(), "unsharded: {}", stderr_of(&out));

    // Two shard processes, then a merge process.
    for spec in ["1/2", "2/2"] {
        let out = repro(
            &["exp", "fig2", "--fast", "--shard", spec, "--out", &s(&shards)],
            &work,
        );
        assert!(out.status.success(), "shard {spec}: {}", stderr_of(&out));
    }
    let out = repro(
        &[
            "exp", "merge", "fig2", "--fast", "--stable-timings", "--out", &s(&shards),
            "--results", &s(&res_merged),
        ],
        &work,
    );
    assert!(out.status.success(), "merge: {}", stderr_of(&out));
    assert!(stdout_of(&out).contains("rendered 'fig2'"), "{}", stdout_of(&out));

    for name in ["fig2.txt", "fig2.csv"] {
        let a = std::fs::read(res_single.join(name)).unwrap();
        let b = std::fs::read(res_merged.join(name)).unwrap();
        assert_eq!(a, b, "{name} differs between unsharded and merged runs");
    }

    // Third way: drive every cell by name alone, then merge the cell
    // record files from a different directory.
    let cells_dir = work.join("cells");
    let plan_out = repro(&["exp", "plan", "fig2", "--fast"], &work);
    assert!(plan_out.status.success());
    let ids: Vec<String> = stdout_of(&plan_out).lines().map(|l| l.to_string()).collect();
    assert_eq!(ids.len(), 2);
    for id in &ids {
        let out = repro(&["exp", "cell", id, "--out", &s(&cells_dir)], &work);
        assert!(out.status.success(), "cell {id}: {}", stderr_of(&out));
    }
    let out = repro(
        &[
            "exp", "merge", "fig2", "--fast", "--stable-timings", "--out", &s(&cells_dir),
            "--results", &s(&res_cells),
        ],
        &work,
    );
    assert!(out.status.success(), "cell merge: {}", stderr_of(&out));
    for name in ["fig2.txt", "fig2.csv"] {
        let a = std::fs::read(res_single.join(name)).unwrap();
        let b = std::fs::read(res_cells.join(name)).unwrap();
        assert_eq!(a, b, "{name} differs between unsharded and cell-driven runs");
    }

    // Merging with a duplicated shard file is a hard error.
    std::fs::copy(
        shards.join("fig2.shard-1-of-2.jsonl"),
        shards.join("fig2.shard-1-of-2-copy.jsonl"),
    )
    .unwrap();
    let out = repro(
        &["exp", "merge", "fig2", "--fast", "--out", &s(&shards), "--results", &s(&res_merged)],
        &work,
    );
    assert!(!out.status.success(), "duplicate records must fail the merge");
    assert!(stderr_of(&out).contains("duplicate"), "{}", stderr_of(&out));

    std::fs::remove_dir_all(&work).ok();
}
