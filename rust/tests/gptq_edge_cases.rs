//! GPTQ edge cases the row-parallel refactor must not break: dead-column
//! pinning (zero Hessian diagonal), `act_order` permutation round-trips,
//! and lazy-batch block sizes that do not divide the column count.

use qep::linalg::{matmul, Mat};
use qep::quant::gptq::Gptq;
use qep::quant::{LayerCtx, QuantConfig, Quantizer};
use qep::util::pool;
use qep::util::rng::Rng;

/// Correlated activations (the regime where compensation matters).
fn make_ctx(m: usize, d: usize, seed: u64) -> LayerCtx {
    let mut rng = Rng::new(seed);
    let base = Mat::randn(m, d, 1.0, &mut rng);
    let mix = Mat::randn(d, d, 0.4, &mut rng);
    let mut x = matmul(&base, &mix);
    for (v, b) in x.data.iter_mut().zip(base.data.iter()) {
        *v += b;
    }
    LayerCtx::from_activations(&x, seed, "edge")
}

fn assert_all_close(a: &Mat, b: &Mat, tol: f32, label: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{label}: shape");
    for (x, y) in a.data.iter().zip(b.data.iter()) {
        assert!((x - y).abs() < tol, "{label}: {x} vs {y}");
    }
}

#[test]
fn dead_columns_stay_pinned_and_deterministic() {
    let mut rng = Rng::new(1);
    let mut x = Mat::randn(128, 16, 1.0, &mut rng);
    for t in 0..x.rows {
        *x.at_mut(t, 3) = 0.0;
        *x.at_mut(t, 11) = 0.0;
    }
    let ctx = LayerCtx::from_activations(&x, 0, "dead");
    let w = Mat::randn(6, 16, 1.0, &mut rng);
    let mut runs = Vec::new();
    for rep in 0..2 {
        let q = Gptq::default().quantize(&w, &QuantConfig::int(3), &ctx).unwrap();
        for r in 0..q.rows {
            assert_eq!(q.at(r, 3), 0.0, "rep={rep} row {r} col 3");
            assert_eq!(q.at(r, 11), 0.0, "rep={rep} row {r} col 11");
        }
        runs.push(q);
    }
    assert_eq!(runs[0], runs[1], "dead-column result not deterministic");
}

#[test]
fn fully_dead_hessian_quantizes_to_zero_without_crashing() {
    // Every calibration activation is zero: all diagonals get pinned, the
    // damped identity keeps the Cholesky alive, and the output is the
    // all-zero matrix.
    let x = Mat::zeros(64, 8);
    let ctx = LayerCtx::from_activations(&x, 0, "allzero");
    let mut rng = Rng::new(2);
    let w = Mat::randn(4, 8, 1.0, &mut rng);
    let q = Gptq::default().quantize(&w, &QuantConfig::int(4), &ctx).unwrap();
    assert!(q.data.iter().all(|&v| v == 0.0));
}

#[test]
fn act_order_roundtrip_recovers_weights_at_high_bits() {
    // With 8 bits the grid is fine enough that quantize(permute(W)) then
    // unpermute must land within a hair of W — any permutation bookkeeping
    // bug (e.g. rows swept in a stale order after the parallel refactor)
    // shows up as gross error here.
    let mut rng = Rng::new(3);
    let ctx = make_ctx(256, 24, 4);
    let w = Mat::randn(6, 24, 1.0, &mut rng);
    let g = Gptq { act_order: true, ..Default::default() };
    let q = g.quantize(&w, &QuantConfig::int(8), &ctx).unwrap();
    assert_eq!((q.rows, q.cols), (6, 24));
    let rel = q.sub(&w).frob() / w.frob();
    assert!(rel < 0.02, "act_order high-bit round-trip rel err {rel}");
}

/// The ONLY test in this binary that touches the process-wide thread
/// setting (GPTQ's internal row sweep reads the global pool). Keeping all
/// `set_global_threads` calls inside one `#[test]` means its forced-serial
/// leg cannot be overwritten by a concurrently running test, so the
/// serial-vs-parallel comparison stays meaningful under cargo's default
/// parallel harness.
#[test]
fn sweep_is_bit_identical_across_forced_global_thread_counts() {
    let mut rng = Rng::new(5);
    let ctx = make_ctx(512, 32, 6);
    let w = Mat::randn(8, 32, 1.0, &mut rng);
    let mut dead_x = Mat::randn(128, 16, 1.0, &mut rng);
    for t in 0..dead_x.rows {
        *dead_x.at_mut(t, 7) = 0.0;
    }
    let dead_ctx = LayerCtx::from_activations(&dead_x, 0, "dead");
    let dead_w = Mat::randn(6, 16, 1.0, &mut rng);

    pool::set_global_threads(1);
    let plain_serial = Gptq::default().quantize(&w, &QuantConfig::int(3), &ctx).unwrap();
    let ordered_serial = Gptq { act_order: true, ..Default::default() }
        .quantize(&w, &QuantConfig::int(3), &ctx)
        .unwrap();
    let dead_serial = Gptq::default().quantize(&dead_w, &QuantConfig::int(3), &dead_ctx).unwrap();

    pool::set_global_threads(4);
    let plain_pooled = Gptq::default().quantize(&w, &QuantConfig::int(3), &ctx).unwrap();
    let ordered_pooled = Gptq { act_order: true, ..Default::default() }
        .quantize(&w, &QuantConfig::int(3), &ctx)
        .unwrap();
    let dead_pooled = Gptq::default().quantize(&dead_w, &QuantConfig::int(3), &dead_ctx).unwrap();

    pool::set_global_threads(0);
    assert_eq!(plain_serial, plain_pooled, "plain sweep");
    assert_eq!(ordered_serial, ordered_pooled, "act_order sweep");
    assert_eq!(dead_serial, dead_pooled, "dead-column sweep");
    for r in 0..dead_pooled.rows {
        assert_eq!(dead_pooled.at(r, 7), 0.0, "dead column unpinned at row {r}");
    }
}

#[test]
fn block_size_not_dividing_columns_matches_unblocked() {
    // d = 37 is prime: every block size below exercises a ragged final
    // block; all must agree with the unblocked sweep up to f32 noise.
    let mut rng = Rng::new(7);
    let ctx = make_ctx(256, 37, 8);
    let w = Mat::randn(8, 37, 1.0, &mut rng);
    let cfg = QuantConfig::int(4);
    let unblocked = Gptq { block_size: 4096, ..Default::default() }
        .quantize(&w, &cfg, &ctx)
        .unwrap();
    for bs in [1usize, 5, 16, 36] {
        let blocked = Gptq { block_size: bs, ..Default::default() }
            .quantize(&w, &cfg, &ctx)
            .unwrap();
        assert_all_close(&blocked, &unblocked, 2e-3, &format!("block_size={bs}"));
    }
}

#[test]
fn group_boundaries_misaligned_with_blocks_still_work() {
    // Group length 10 on d = 37 with block size 16: group refits land
    // mid-block and the last group is ragged. The sweep must stay finite,
    // deterministic, and better than not compensating at all.
    let mut rng = Rng::new(9);
    let ctx = make_ctx(256, 37, 10);
    let w = Mat::randn(8, 37, 1.0, &mut rng);
    let cfg = QuantConfig::int_group(3, 10);
    let g = Gptq { block_size: 16, ..Default::default() };
    let a = g.quantize(&w, &cfg, &ctx).unwrap();
    let b = g.quantize(&w, &cfg, &ctx).unwrap();
    assert_eq!(a, b, "misaligned groups must stay deterministic");
    assert!(a.data.iter().all(|v| v.is_finite()));
    let unblocked = Gptq { block_size: 4096, ..Default::default() }
        .quantize(&w, &cfg, &ctx)
        .unwrap();
    assert_all_close(&a, &unblocked, 2e-3, "grouped blocked vs unblocked");
}
