//! Serial-equivalence suite for the parallel execution engine.
//!
//! The contract under test: the `threads` knob NEVER changes results. For
//! every method × bit-width × QEP setting, a pipeline run with `threads=1`
//! must produce a model bit-identical to `threads=4` — same floats, same
//! serialized `.qtz` bytes — and runs must stay deterministic given a seed
//! while the pool is active. The same contract covers the blocked SPD
//! engine (every thread count AND every block size), the pooled
//! perplexity/task evaluation, and the sharded experiment sweeps (table
//! renders must be byte-identical across `--threads`). Since the pool
//! became persistent (parked workers instead of per-dispatch scoped
//! spawns), the suite additionally pins the persistent engine against the
//! kept scoped-spawn baseline (`Pool::run_scoped`): both must execute the
//! exact same work. This is what lets the repo claim the paper's
//! "lightweight and scalable" axis without giving up reproducibility.

use qep::coordinator::{Pipeline, PipelineConfig, PipelineOutput};
use qep::eval::perplexity_with;
use qep::exp::tables::{format_acc_table, format_ppl_table, matrix, run_matrix_on, Wants};
use qep::exp::{Cell, ExpData};
use qep::linalg::{
    cholesky_in_place_with, cholesky_unblocked, spd_solve_with, upper_cholesky_of_inverse_with,
    Mat64,
};
use qep::model::{BlockWeights, Model, ModelConfig, Size};
use qep::quant::{Alloc, BitBudget, BudgetSpec, Method, QuantConfig};
use qep::text::{Corpus, Flavor};
use qep::util::pool::Pool;
use qep::util::rng::Rng;
use std::collections::HashMap;

fn setup() -> (Model, Vec<u32>) {
    let mut cfg = ModelConfig::new("unit", 16, 2, 2, 32);
    cfg.seq_len = 8;
    let model = Model::random(&cfg, 1);
    let mut rng = Rng::new(2);
    let tokens: Vec<u32> = (0..8 * 16).map(|_| rng.below(256) as u32).collect();
    (model, tokens)
}

fn quantize(
    model: &Model,
    tokens: &[u32],
    method: Method,
    bits: u32,
    qep_alpha: Option<f32>,
    threads: usize,
) -> Model {
    let cfg = PipelineConfig {
        quant: QuantConfig::int(bits),
        method,
        qep_alpha,
        seed: 42,
        threads,
        ..Default::default()
    };
    Pipeline::new(cfg).run(model, tokens).unwrap().model
}

fn assert_models_bit_identical(a: &Model, b: &Model, label: &str) {
    assert_eq!(a.embed, b.embed, "{label}: embed");
    assert_eq!(a.final_norm, b.final_norm, "{label}: final_norm");
    assert_eq!(a.blocks.len(), b.blocks.len(), "{label}: block count");
    for (i, (ba, bb)) in a.blocks.iter().zip(b.blocks.iter()).enumerate() {
        for name in BlockWeights::LINEAR_NAMES {
            assert_eq!(
                ba.linear(name),
                bb.linear(name),
                "{label}: block {i} {name} differs between thread counts"
            );
        }
        assert_eq!(ba.attn_norm, bb.attn_norm, "{label}: block {i} attn_norm");
        assert_eq!(ba.mlp_norm, bb.mlp_norm, "{label}: block {i} mlp_norm");
    }
}

#[test]
fn every_method_bits_qep_combo_is_thread_count_invariant() {
    let (model, tokens) = setup();
    for method in Method::all() {
        for bits in [3u32, 4] {
            for qep_alpha in [None, Some(0.5)] {
                let label = format!("{method:?} int{bits} qep={qep_alpha:?}");
                let serial = quantize(&model, &tokens, method, bits, qep_alpha, 1);
                let pooled = quantize(&model, &tokens, method, bits, qep_alpha, 4);
                assert_models_bit_identical(&serial, &pooled, &label);
            }
        }
    }
}

#[test]
fn deterministic_given_seed_under_the_pool() {
    let (model, tokens) = setup();
    for method in [Method::Gptq, Method::Quip] {
        let a = quantize(&model, &tokens, method, 3, Some(0.5), 4);
        let b = quantize(&model, &tokens, method, 3, Some(0.5), 4);
        assert_models_bit_identical(&a, &b, &format!("{method:?} repeat @ threads=4"));
    }
}

#[test]
fn oversubscribed_and_odd_thread_counts_agree() {
    // More workers than rows/layers, and a thread count that divides
    // nothing evenly, must still match the serial reference.
    let (model, tokens) = setup();
    let serial = quantize(&model, &tokens, Method::Gptq, 3, Some(0.5), 1);
    for threads in [3usize, 7, 16] {
        let pooled = quantize(&model, &tokens, Method::Gptq, 3, Some(0.5), threads);
        assert_models_bit_identical(&serial, &pooled, &format!("threads={threads}"));
    }
}

#[test]
fn qtz_files_are_byte_identical_across_thread_counts() {
    let (model, tokens) = setup();
    let serial = quantize(&model, &tokens, Method::Gptq, 3, Some(0.5), 1);
    let pooled = quantize(&model, &tokens, Method::Gptq, 3, Some(0.5), 4);
    let dir = std::env::temp_dir();
    let p1 = dir.join("qep_parallel_equiv_t1.qtz");
    let p4 = dir.join("qep_parallel_equiv_t4.qtz");
    serial.save(&p1).unwrap();
    pooled.save(&p4).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    let b4 = std::fs::read(&p4).unwrap();
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p4).ok();
    assert!(!b1.is_empty());
    assert_eq!(b1, b4, ".qtz bytes differ between threads=1 and threads=4");
}

#[test]
fn pipelined_calibration_and_cbq_windows_are_thread_count_invariant() {
    // The software-pipelined calibration stage (threads > 1 runs the
    // forward pass for block b+1 concurrently with block b's
    // quantization) must keep .qtz bytes identical to the serial
    // schedule, for every thread count and every CBQ window. A 4-block
    // model makes window 2 genuinely refine (its second window starts
    // past block 0) instead of degenerating to the layer-wise path.
    let mut cfg = ModelConfig::new("unit", 16, 4, 2, 32);
    cfg.seq_len = 8;
    let model = Model::random(&cfg, 1);
    let mut rng = Rng::new(2);
    let tokens: Vec<u32> = (0..8 * 16).map(|_| rng.below(256) as u32).collect();
    let run = |threads: usize, window: usize| -> Vec<u8> {
        let cfg = PipelineConfig {
            quant: QuantConfig::int(3),
            method: Method::Gptq,
            qep_alpha: Some(0.5),
            cbq_window: window,
            seed: 42,
            threads,
            ..Default::default()
        };
        let out = Pipeline::new(cfg).run(&model, &tokens).unwrap();
        let p = std::env::temp_dir().join(format!("qep_cbq_equiv_t{threads}_w{window}.qtz"));
        out.model.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        bytes
    };
    for window in [1usize, 2, 3] {
        let serial = run(1, window);
        assert!(!serial.is_empty());
        for threads in [2usize, 8] {
            assert_eq!(
                run(threads, window),
                serial,
                "cbq window {window}: .qtz bytes differ between threads=1 and threads={threads}"
            );
        }
    }
}

#[test]
fn lowrank_qtz_files_are_byte_identical_across_thread_counts() {
    // The adjunct-carrying artifact (base weights + lowrank.* sections)
    // inherits the byte-identity contract: the SVD seeds derive from
    // layer names and the Jacobi/range-finder kernels fix their
    // reduction orders, so threads only trade wall-clock.
    let (model, tokens) = setup();
    let run_lr = |threads: usize| {
        let cfg = PipelineConfig {
            quant: QuantConfig::int(3),
            method: Method::Gptq,
            qep_alpha: Some(0.5),
            lowrank_rank: 2,
            seed: 42,
            threads,
            ..Default::default()
        };
        Pipeline::new(cfg).run(&model, &tokens).unwrap()
    };
    let a = run_lr(1);
    let b = run_lr(4);
    assert_models_bit_identical(&a.model, &b.model, "lowrank effective model");
    let dir = std::env::temp_dir();
    let p1 = dir.join("qep_lowrank_equiv_t1.qtz");
    let p4 = dir.join("qep_lowrank_equiv_t4.qtz");
    qep::qep::save_with_adjuncts(&p1, a.base_model.as_ref().unwrap(), &a.adjuncts, 2).unwrap();
    qep::qep::save_with_adjuncts(&p4, b.base_model.as_ref().unwrap(), &b.adjuncts, 2).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    let b4 = std::fs::read(&p4).unwrap();
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p4).ok();
    assert!(!b1.is_empty());
    assert_eq!(b1, b4, "low-rank .qtz bytes differ between threads=1 and threads=4");
}

#[test]
fn budget_allocation_and_qtz_meta_are_thread_invariant() {
    // Mixed-precision budgets ride the same contract: the Hessian-diag
    // scoring pre-pass and both allocators are serial over a canonical
    // layer order, so the per-layer bit map, the quantized model, and the
    // serialized .qtz bytes (allocation meta included) never depend on
    // the pool width.
    let (model, tokens) = setup();
    let run_b = |threads: usize| -> PipelineOutput {
        let cfg = PipelineConfig {
            quant: QuantConfig::int(7), // superseded by the budget's floor
            method: Method::Gptq,
            qep_alpha: Some(0.5),
            bit_budget: Some(BudgetSpec {
                budget: BitBudget::from_decibits(25),
                alloc: Alloc::Dp,
            }),
            seed: 42,
            threads,
            ..Default::default()
        };
        Pipeline::new(cfg).run(&model, &tokens).unwrap()
    };
    let a = run_b(1);
    let alloc_a = a.allocation.as_ref().expect("budget run must produce an allocation");
    assert!(alloc_a.avg_bits >= 2.0 && alloc_a.avg_bits <= 2.5, "{}", alloc_a.summary());
    let b = run_b(8);
    for (threads, out) in [(2usize, run_b(2)), (8, b)] {
        assert_eq!(
            Some(alloc_a),
            out.allocation.as_ref(),
            "allocation differs at threads={threads}"
        );
        assert_models_bit_identical(&a.model, &out.model, &format!("budget threads={threads}"));

        let dir = std::env::temp_dir();
        let write_qtz = |out: &PipelineOutput, name: &str| -> Vec<u8> {
            let mut tf = out.model.to_tensor_file();
            qep::quant::budget::write_allocation_meta(&mut tf.meta, out.allocation.as_ref().unwrap());
            let p = dir.join(name);
            tf.save(&p).unwrap();
            let bytes = std::fs::read(&p).unwrap();
            std::fs::remove_file(&p).ok();
            bytes
        };
        let b1 = write_qtz(&a, "qep_budget_equiv_a.qtz");
        let bt = write_qtz(&out, "qep_budget_equiv_b.qtz");
        assert!(!b1.is_empty());
        assert_eq!(b1, bt, "budget .qtz bytes differ between threads=1 and threads={threads}");
    }
}

#[test]
fn budget_cells_are_thread_invariant() {
    // An allocated budget cell through the full sweep machinery (cell →
    // scoring pre-pass → pipeline → ppl) must match across pool widths,
    // like every other cell — alongside its uniform-floor twin.
    let mut cfg = ModelConfig::new("tiny-s", 16, 2, 2, 32);
    cfg.seq_len = 8;
    let model = Model::random(&cfg, 3);
    let mut models = HashMap::new();
    models.insert(Size::TinyS.name().to_string(), model);
    let mut corpora = HashMap::new();
    for f in Flavor::all() {
        corpora.insert(f, Corpus::generate(f, 24 * 1024, 0));
    }
    let data = ExpData::from_parts(models, corpora);

    let uniform = Cell::new(Size::TinyS, Method::Gptq, QuantConfig::int(2), true);
    let mut allocated = uniform.clone();
    allocated.budget = Some(BudgetSpec {
        budget: BitBudget::from_decibits(25),
        alloc: Alloc::Dp,
    });
    let cells = vec![uniform, allocated];
    let wants = Wants { ppl: vec![Flavor::Wiki], tasks: vec![] };
    let run = |threads: usize| -> Vec<u64> {
        run_matrix_on(&data, &cells, &wants, &Pool::new(threads))
            .unwrap()
            .iter()
            .map(|r| r.ppl[&Flavor::Wiki].to_bits())
            .collect()
    };
    let want = run(1);
    for threads in [2usize, 8] {
        assert_eq!(run(threads), want, "budget cell ppl differs at threads={threads}");
    }
}

fn random_spd(n: usize, rng: &mut Rng) -> Mat64 {
    // A = B·Bᵀ + n·I — well conditioned SPD, built in f64.
    let mut b = Mat64::zeros(n, n);
    for v in b.data.iter_mut() {
        *v = rng.normal();
    }
    let mut a = Mat64::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += b.at(i, k) * b.at(j, k);
            }
            *a.at_mut(i, j) = s;
        }
    }
    a.add_diag(n as f64);
    a
}

#[test]
fn spd_engine_is_thread_and_block_invariant() {
    let mut rng = Rng::new(9);
    let n = 96;
    let a = random_spd(n, &mut rng);

    // Factorization: every block size × thread count reproduces the
    // unblocked serial reference bit-for-bit.
    let mut want = a.clone();
    cholesky_unblocked(&mut want).unwrap();
    for block in [7usize, 32, 96, 128] {
        for threads in [1usize, 2, 8] {
            let mut got = a.clone();
            cholesky_in_place_with(&mut got, block, &Pool::new(threads)).unwrap();
            assert_eq!(got.data, want.data, "chol block={block} threads={threads}");
        }
    }

    // Multi-RHS solve: column strips across workers, same bits.
    let mut b = Mat64::zeros(n, 17);
    for v in b.data.iter_mut() {
        *v = rng.normal();
    }
    let want_x = spd_solve_with(&a, &b, &Pool::serial()).unwrap();
    for threads in [2usize, 3, 8] {
        let got = spd_solve_with(&a, &b, &Pool::new(threads)).unwrap();
        assert_eq!(got.data, want_x.data, "spd_solve threads={threads}");
    }

    // GPTQ's factor (inverse + re-factor + transpose) end to end.
    let want_u = upper_cholesky_of_inverse_with(&a, &Pool::serial()).unwrap();
    for threads in [2usize, 8] {
        let got = upper_cholesky_of_inverse_with(&a, &Pool::new(threads)).unwrap();
        assert_eq!(got.data, want_u.data, "chol_of_inv threads={threads}");
    }
}

#[test]
fn persistent_pool_matches_scoped_spawn_baseline_exactly() {
    // The persistent-worker engine and the scoped-spawn baseline must
    // execute identical work: same chunk coverage, same per-index
    // results, for a mix of sizes, grains, and thread counts.
    use qep::util::pool::SendPtr;
    for (n, grain) in [(1usize, 1usize), (13, 4), (256, 16), (1000, 7)] {
        for threads in [2usize, 4, 7] {
            let pool = Pool::new(threads);
            let run_engine = |persistent: bool| -> Vec<u64> {
                let mut out = vec![u64::MAX; n];
                {
                    let base = SendPtr::new(out.as_mut_ptr());
                    let f = |s: usize, e: usize| {
                        for i in s..e {
                            // Sound: chunks are disjoint index ranges.
                            unsafe { *base.0.add(i) = (i as u64).wrapping_mul(0x9e3779b9) };
                        }
                    };
                    if persistent {
                        pool.run(n, grain, f);
                    } else {
                        pool.run_scoped(n, grain, f);
                    }
                }
                out
            };
            let persistent = run_engine(true);
            let scoped = run_engine(false);
            assert_eq!(persistent, scoped, "n={n} grain={grain} threads={threads}");
            assert!(
                persistent.iter().all(|&v| v != u64::MAX),
                "n={n} grain={grain} threads={threads}: uncovered index"
            );
        }
    }
}

#[test]
fn spd_engine_matches_scoped_dispatch_bit_for_bit() {
    // The full blocked Cholesky through the persistent pool must equal the
    // serial reference (and therefore the scoped-spawn engine, which the
    // pre-persistent suite pinned to the same reference).
    let mut rng = Rng::new(21);
    let n = 80;
    let a = random_spd(n, &mut rng);
    let mut want = a.clone();
    cholesky_unblocked(&mut want).unwrap();
    for threads in [2usize, 8] {
        let mut got = a.clone();
        cholesky_in_place_with(&mut got, 32, &Pool::new(threads)).unwrap();
        assert_eq!(got.data, want.data, "threads={threads}");
    }
}

#[test]
fn pooled_perplexity_is_thread_invariant() {
    let (model, tokens) = setup();
    let want = perplexity_with(&model, &tokens, 2, &Pool::serial());
    for threads in [2usize, 5, 8] {
        assert_eq!(
            perplexity_with(&model, &tokens, 2, &Pool::new(threads)),
            want,
            "threads={threads}"
        );
    }
}

#[test]
fn exp_tables_are_byte_identical_across_thread_counts() {
    // A full sharded sweep — quantize, evaluate ppl + tasks, render the
    // paper-layout tables — must produce the same bytes for --threads
    // 1/2/8. Tiny injected model + small corpora keep this fast.
    let mut cfg = ModelConfig::new("tiny-s", 16, 2, 2, 32);
    cfg.seq_len = 8;
    let model = Model::random(&cfg, 3);
    let mut models = HashMap::new();
    models.insert(Size::TinyS.name().to_string(), model);
    let mut corpora = HashMap::new();
    for f in Flavor::all() {
        corpora.insert(f, Corpus::generate(f, 24 * 1024, 0));
    }
    let data = ExpData::from_parts(models, corpora);

    let sizes = [Size::TinyS];
    let settings = [QuantConfig::int(3)];
    let methods = [Method::Rtn, Method::Gptq];
    let cells = matrix(&sizes, &settings, &methods);
    let wants = Wants { ppl: vec![Flavor::Wiki], tasks: vec![qep::eval::TaskFamily::Cloze] };

    let render = |threads: usize| -> (String, String) {
        let results = run_matrix_on(&data, &cells, &wants, &Pool::new(threads)).unwrap();
        let t1 = format_ppl_table("t1", &results, &sizes, &settings, &methods, Flavor::Wiki);
        let t2 = format_acc_table("t2", &results, &sizes, &settings, &methods, None);
        (t1.render(), t2.render())
    };
    let (ppl1, acc1) = render(1);
    for threads in [2usize, 8] {
        let (ppl_t, acc_t) = render(threads);
        assert_eq!(ppl1, ppl_t, "ppl table bytes differ at threads={threads}");
        assert_eq!(acc1, acc_t, "acc table bytes differ at threads={threads}");
    }
    // The tables contain real numbers, not N/A placeholders (a cell that
    // failed to match would render as N/A).
    assert!(!ppl1.contains("N/A"), "{ppl1}");
}

#[test]
fn fused_qgemm_is_thread_invariant_and_matches_dequantize_matmul() {
    // The fused dequantize×GEMM path must equal dequantize-then-matmul
    // bit-for-bit, at every thread count — it is the serving engine's
    // quantized hot loop.
    use qep::linalg::{matmul_nt_serial, qgemm_nt_with, Mat};
    use qep::quant::QuantizedTensor;
    let mut rng = Rng::new(33);
    for (m, k, n) in [(1usize, 64usize, 48usize), (4, 96, 96), (9, 64, 31)] {
        let x = Mat::randn(m, k, 1.0, &mut rng);
        let w = Mat::randn(n, k, 1.0, &mut rng);
        let q = QuantizedTensor::from_mat(&w, &QuantConfig::int_group(4, 32));
        let want = matmul_nt_serial(&x, &q.dequantize());
        for threads in [1usize, 2, 5, 8] {
            let got = qgemm_nt_with(&x, &q.view(), &Pool::new(threads));
            assert_eq!(got.data, want.data, "m={m} k={k} n={n} threads={threads}");
        }
    }
}

#[test]
fn serving_completions_are_thread_invariant() {
    // End-to-end: the continuous-batching scheduler over the quantized
    // engine produces identical completions for every thread count.
    use qep::serve::{FinishReason, Scheduler, ServeConfig, ServeModel};
    let (model, _) = setup();
    let qm = ServeModel::quantized(&model, &QuantConfig::int_group(4, 8));
    let prompts: Vec<Vec<u32>> = vec![vec![10, 20, 30], vec![40], vec![50, 60, 70, 80]];
    let run = |threads: usize| -> Vec<(usize, Vec<u32>, FinishReason)> {
        let mut s = Scheduler::new(
            qm.clone(),
            ServeConfig { max_batch: 2, max_new_tokens: 4 },
            Pool::new(threads),
        );
        for p in &prompts {
            s.submit(p).unwrap();
        }
        s.run().into_iter().map(|c| (c.id, c.tokens, c.finish)).collect()
    };
    let want = run(1);
    for threads in [2usize, 4, 7] {
        assert_eq!(run(threads), want, "threads={threads}");
    }
}

#[test]
fn kv_decode_matches_full_forward_across_thread_counts() {
    // decode_step's KV-cached incremental path must reproduce the full
    // recompute bit-for-bit; the full forward itself must not depend on
    // the global pool width either (linears route through it).
    use qep::model::Forward;
    use qep::serve::KvCache;
    use qep::util::pool::set_global_threads;
    let (model, tokens) = setup();
    let cfg = &model.cfg;
    let f = Forward::new(cfg);
    let seg = &tokens[..cfg.seq_len];
    let want = f.forward(&model, seg);
    for threads in [1usize, 4] {
        set_global_threads(threads);
        let mut cache = KvCache::new(cfg.n_layers, cfg.seq_len, cfg.dim);
        for (t, &tok) in seg.iter().enumerate() {
            let logits = f.decode_step(&model, &mut cache, tok);
            assert_eq!(logits.row(0), want.row(t), "threads={threads} t={t}");
        }
    }
    set_global_threads(0);
}

#[test]
fn reports_match_across_thread_counts() {
    // Recon errors and layer ordering in the report are part of the
    // deterministic surface (timings are not).
    let (model, tokens) = setup();
    let cfg = |threads: usize| PipelineConfig {
        quant: QuantConfig::int(3),
        method: Method::Gptq,
        qep_alpha: Some(0.5),
        seed: 7,
        threads,
        ..Default::default()
    };
    let a = Pipeline::new(cfg(1)).run(&model, &tokens).unwrap().report;
    let b = Pipeline::new(cfg(4)).run(&model, &tokens).unwrap().report;
    assert_eq!(a.layers.len(), b.layers.len());
    for (la, lb) in a.layers.iter().zip(b.layers.iter()) {
        assert_eq!(la.name, lb.name, "layer order must be canonical");
        assert_eq!(la.recon_error, lb.recon_error, "{}", la.name);
        assert_eq!(la.alpha, lb.alpha, "{}", la.name);
    }
}
