//! The crash-safety headline gate, across real processes: a shard of
//! `repro exp` SIGKILLed mid-sweep and re-run with `--resume` must
//! produce a record file — and, after merging with its sibling shard,
//! rendered tables — **byte-identical** to an uninterrupted run
//! (`--stable-timings` zeroes the only non-deterministic record bytes,
//! the shard-local wall-clock fields). Also drives the non-empty-dir
//! guard, torn-tail truncation, and `exp status` end to end.
//! CI runs the same choreography on `exp table12` in its
//! kill-and-resume job; this is the local, always-on counterpart.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::process::{Command, Output, Stdio};
use std::time::{Duration, Instant};

const SWEEP: &str = "ablation-alpha"; // 5 fast RTN-only cells under --fast
const SHARD_FILE_1: &str = "ablation-alpha.shard-1-of-2.jsonl";
const SHARD_FILE_2: &str = "ablation-alpha.shard-2-of-2.jsonl";

fn repro(args: &[&str], cwd: &Path) -> Output {
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .current_dir(cwd)
        .output()
        .expect("repro binary runs")
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn stdout_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("qep_cli_resume_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Every file in a directory, name → bytes (for byte-identity asserts).
fn dir_bytes(dir: &Path) -> Vec<(String, Vec<u8>)> {
    let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("read {}: {e}", dir.display()))
        .map(|e| {
            let p = e.unwrap().path();
            (p.file_name().unwrap().to_string_lossy().into_owned(), std::fs::read(&p).unwrap())
        })
        .collect();
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

fn assert_dirs_equal(want: &Path, got: &Path, what: &str) {
    let (w, g) = (dir_bytes(want), dir_bytes(got));
    assert_eq!(
        w.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
        g.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
        "{what}: file sets differ"
    );
    for ((name, a), (_, b)) in w.iter().zip(g.iter()) {
        assert_eq!(a, b, "{what}: '{name}' differs");
    }
}

#[test]
fn killed_shard_resumes_to_byte_identical_records_and_renders() {
    let work = tmp("e2e");
    let ref_shards = work.join("ref_shards");
    let kill_shards = work.join("kill_shards");
    let res_ref = work.join("res_ref");
    let res_single = work.join("res_single");
    let res_kill = work.join("res_kill");
    let s = |p: &PathBuf| p.to_str().unwrap().to_string();

    // --- Reference legs: an uninterrupted 2-shard run merged, and an
    // uninterrupted unsharded render.
    for spec in ["1/2", "2/2"] {
        let out = repro(
            &[
                "exp", SWEEP, "--fast", "--stable-timings", "--shard", spec, "--out",
                &s(&ref_shards),
            ],
            &work,
        );
        assert!(out.status.success(), "reference shard {spec}: {}", stderr_of(&out));
    }
    let out = repro(
        &[
            "exp", "merge", SWEEP, "--fast", "--stable-timings", "--out", &s(&ref_shards),
            "--results", &s(&res_ref),
        ],
        &work,
    );
    assert!(out.status.success(), "reference merge: {}", stderr_of(&out));
    let out = repro(
        &["exp", SWEEP, "--fast", "--stable-timings", "--results", &s(&res_single)],
        &work,
    );
    assert!(out.status.success(), "unsharded reference: {}", stderr_of(&out));
    assert_dirs_equal(&res_single, &res_ref, "uninterrupted merged vs unsharded renders");

    // --- Killed leg: start shard 1/2, SIGKILL it as soon as the first
    // record has durably landed.
    let target = kill_shards.join(SHARD_FILE_1);
    let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "exp", SWEEP, "--fast", "--stable-timings", "--shard", "1/2", "--out",
            &s(&kill_shards),
        ])
        .current_dir(&work)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn shard to kill");
    let deadline = Instant::now() + Duration::from_secs(300);
    loop {
        let first_record_landed = std::fs::read(&target)
            .map(|b| b.contains(&b'\n'))
            .unwrap_or(false);
        let exited = child.try_wait().expect("try_wait").is_some();
        if first_record_landed || exited {
            break;
        }
        assert!(Instant::now() < deadline, "no record landed within the deadline");
        std::thread::sleep(Duration::from_millis(10));
    }
    child.kill().ok(); // SIGKILL — no cleanup handlers run
    let status = child.wait().expect("wait for killed child");
    // Either we killed it mid-sweep (the interesting case) or it was so
    // fast it finished first (every assert below still must hold).
    if status.success() {
        eprintln!(
            "[test] note: shard finished before the kill landed; exercising the no-op resume"
        );
    }
    assert!(target.exists(), "the durable record file must exist after the kill");

    // Deterministically exercise torn-tail recovery: append an
    // unterminated fragment, as if the kill had landed mid-`write`.
    {
        let mut f = std::fs::OpenOptions::new().append(true).open(&target).unwrap();
        f.write_all(br#"{"id":"ablation-alpha/a0.00/ti"#).unwrap();
    }

    // --- The non-empty-target guard: re-running WITHOUT --resume must
    // refuse, pointing at --resume.
    let out = repro(
        &[
            "exp", SWEEP, "--fast", "--stable-timings", "--shard", "1/2", "--out",
            &s(&kill_shards),
        ],
        &work,
    );
    assert!(!out.status.success(), "fresh run into interrupted dir must fail");
    let err = stderr_of(&out);
    assert!(err.contains("--resume"), "guard must point at --resume: {err}");

    // Resuming with mismatched plan flags is a hard error (parameter
    // mismatch: under --sizes tiny-m the manifest holds only tiny-m
    // cells, so the tiny-s records on disk don't belong to it).
    let out = repro(
        &[
            "exp", SWEEP, "--stable-timings", "--sizes", "tiny-m", "--shard", "1/2", "--out",
            &s(&kill_shards), "--resume",
        ],
        &work,
    );
    assert!(!out.status.success(), "resume under different flags must fail");
    let err = stderr_of(&out);
    assert!(err.contains("not in this manifest"), "{err}");

    // --- Resume (same flags), finish the sibling shard, check status,
    // merge.
    let out = repro(
        &[
            "exp", SWEEP, "--fast", "--stable-timings", "--shard", "1/2", "--out",
            &s(&kill_shards), "--resume",
        ],
        &work,
    );
    assert!(out.status.success(), "resume: {}", stderr_of(&out));
    let out = repro(
        &[
            "exp", SWEEP, "--fast", "--stable-timings", "--shard", "2/2", "--out",
            &s(&kill_shards),
        ],
        &work,
    );
    assert!(out.status.success(), "sibling shard: {}", stderr_of(&out));

    let out = repro(
        &["exp", "status", SWEEP, "--fast", "--out", &s(&kill_shards)],
        &work,
    );
    assert!(out.status.success(), "status: {}", stderr_of(&out));
    let st = stdout_of(&out);
    assert!(st.contains("5/5 cell(s) done"), "{st}");
    assert!(st.contains("ready to `repro exp merge`"), "{st}");

    let out = repro(
        &[
            "exp", "merge", SWEEP, "--fast", "--stable-timings", "--out", &s(&kill_shards),
            "--results", &s(&res_kill),
        ],
        &work,
    );
    assert!(out.status.success(), "merge after resume: {}", stderr_of(&out));

    // --- The headline asserts: record files AND renders byte-identical
    // to the uninterrupted run.
    for name in [SHARD_FILE_1, SHARD_FILE_2] {
        let want = std::fs::read(ref_shards.join(name)).unwrap();
        let got = std::fs::read(kill_shards.join(name)).unwrap();
        assert_eq!(
            want, got,
            "{name}: killed+resumed record file differs from uninterrupted"
        );
    }
    assert_dirs_equal(&res_ref, &res_kill, "killed+resumed renders vs uninterrupted");

    std::fs::remove_dir_all(&work).ok();
}

/// The unsharded durable path: `--out` without `--shard` appends durably
/// too, refuses a non-empty directory without `--resume`, and resumes to
/// records byte-identical to an uninterrupted unsharded run.
#[test]
fn unsharded_out_runs_are_durable_and_resumable() {
    let work = tmp("unsharded");
    let a = work.join("a");
    let b = work.join("b");
    let res_a = work.join("res_a");
    let res_b = work.join("res_b");
    let s = |p: &PathBuf| p.to_str().unwrap().to_string();
    let file = "ablation-alpha.shard-1-of-1.jsonl";

    // Uninterrupted reference with records + renders.
    let out = repro(
        &[
            "exp", SWEEP, "--fast", "--stable-timings", "--out", &s(&a), "--results",
            &s(&res_a),
        ],
        &work,
    );
    assert!(out.status.success(), "reference: {}", stderr_of(&out));

    // A second fresh run into the same non-empty dir is a hard error.
    let out = repro(
        &["exp", SWEEP, "--fast", "--stable-timings", "--out", &s(&a)],
        &work,
    );
    assert!(!out.status.success(), "fresh unsharded run into non-empty dir must fail");
    assert!(stderr_of(&out).contains("--resume"), "{}", stderr_of(&out));

    // Interrupted-then-resumed leg: seed dir `b` with a prefix of the
    // reference file plus a torn fragment (what a SIGKILL leaves), then
    // resume; the result must be byte-identical to the reference.
    let ref_bytes = std::fs::read(a.join(file)).unwrap();
    let first_line_end = ref_bytes.iter().position(|&c| c == b'\n').unwrap() + 1;
    std::fs::create_dir_all(&b).unwrap();
    let mut prefix = ref_bytes[..first_line_end].to_vec();
    prefix.extend_from_slice(br#"{"id":"ablation-"#);
    std::fs::write(b.join(file), &prefix).unwrap();

    let out = repro(
        &[
            "exp", SWEEP, "--fast", "--stable-timings", "--out", &s(&b), "--resume",
            "--results", &s(&res_b),
        ],
        &work,
    );
    assert!(out.status.success(), "unsharded resume: {}", stderr_of(&out));
    assert!(
        stderr_of(&out).contains("truncated torn tail"),
        "resume must report the truncation: {}",
        stderr_of(&out)
    );
    assert_eq!(
        std::fs::read(b.join(file)).unwrap(),
        ref_bytes,
        "resumed unsharded record file differs from uninterrupted"
    );
    assert_dirs_equal(&res_a, &res_b, "resumed unsharded renders vs uninterrupted");

    std::fs::remove_dir_all(&work).ok();
}
