//! End-to-end pipeline integration: quantize real (trained, if artifacts
//! exist) models and check the paper's qualitative claims hold on this
//! stack — QEP reduces perplexity, errors accumulate without it, the
//! runtime ordering of Table 3 holds, and quantized models serialize.

use qep::coordinator::{Pipeline, PipelineConfig};
use qep::eval::{delta_per_block, perplexity};
use qep::model::{BlockWeights, Model, ModelConfig, Size};
use qep::quant::{Method, QuantConfig};
use qep::runtime::ArtifactRegistry;
use qep::text::{Corpus, Flavor};
use qep::util::rng::Rng;

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

/// Trained tiny-s when artifacts exist, random fallback otherwise.
fn subject() -> (Model, bool) {
    let reg = registry();
    match reg.load_model(Size::TinyS.name()) {
        Ok(m) => (m, true),
        Err(_) => (Model::random(&Size::TinyS.config(), 7), false),
    }
}

fn calib(model: &Model) -> Vec<u32> {
    let reg = registry();
    let corpus = reg
        .load_corpus(Flavor::C4)
        .unwrap_or_else(|_| Corpus::generate(Flavor::C4, 64 * 1024, 0));
    corpus.tokens[..16 * model.cfg.seq_len].to_vec()
}

fn eval_tokens(model: &Model) -> Vec<u32> {
    let reg = registry();
    let corpus = reg
        .load_corpus(Flavor::Wiki)
        .unwrap_or_else(|_| Corpus::generate(Flavor::Wiki, 64 * 1024, 0));
    let n = 32 * model.cfg.seq_len;
    corpus.tokens[corpus.tokens.len() - n..].to_vec()
}

#[test]
fn qep_improves_trained_model_ppl_at_int3() {
    let (model, trained) = subject();
    let calib = calib(&model);
    let eval = eval_tokens(&model);
    let run = |qep: Option<f32>| {
        let out = Pipeline::new(PipelineConfig {
            quant: QuantConfig::int(3),
            method: Method::Rtn,
            qep_alpha: qep,
            ..Default::default()
        })
        .run(&model, &calib)
        .unwrap();
        perplexity(&out.model, &eval)
    };
    let base = run(None);
    let qep = run(Some(0.5));
    eprintln!("[int3 rtn] trained={trained} base={base:.3} qep={qep:.3}");
    assert!(base.is_finite() && qep.is_finite());
    if trained {
        // The paper's core claim, on our trained substrate.
        assert!(qep < base, "QEP {qep} !< BASE {base}");
    }
}

#[test]
fn quantized_model_roundtrips_through_qtz() {
    let (model, _) = subject();
    let calib = calib(&model);
    let out = Pipeline::new(PipelineConfig {
        quant: QuantConfig::int_group(3, 32),
        method: Method::Gptq,
        qep_alpha: Some(0.5),
        ..Default::default()
    })
    .run(&model, &calib)
    .unwrap();
    let path = std::env::temp_dir().join("qep_integration_roundtrip.qtz");
    out.model.save(&path).unwrap();
    let back = Model::load(&path).unwrap();
    assert_eq!(back.blocks[0].wq, out.model.blocks[0].wq);
    std::fs::remove_file(&path).ok();
}

#[test]
fn fig2_shape_error_grows_and_qep_damps_it() {
    let (model, _) = subject();
    let calib = calib(&model);
    let probe = &eval_tokens(&model)[..4 * model.cfg.seq_len];
    let n_q = model.cfg.n_layers / 2;
    let run = |qep: Option<f32>| {
        let out = Pipeline::new(PipelineConfig {
            quant: QuantConfig::int(2),
            method: Method::Rtn,
            qep_alpha: qep,
            max_blocks: Some(n_q),
            ..Default::default()
        })
        .run(&model, &calib)
        .unwrap();
        delta_per_block(&model, &out.model, probe)
    };
    let base = run(None);
    let qep = run(Some(0.5));
    // Growth through the full-precision suffix (Fig. 2's key observation).
    assert!(base[n_q..].iter().all(|&d| d > 0.0), "{base:?}");
    // QEP ends lower.
    assert!(qep.last().unwrap() < base.last().unwrap(), "qep {qep:?} base {base:?}");
}

#[test]
fn table3_runtime_ordering_holds() {
    // QEP+RTN must cost less than GPTQ and AWQ on the same layer set
    // (the paper's Table 3: 10.9m < 13.6m < 14.9m for 7B).
    let (model, _) = subject();
    let calib = calib(&model);
    let time_of = |method: Method, qep: Option<f32>| {
        let out = Pipeline::new(PipelineConfig {
            quant: QuantConfig::int(3),
            method,
            qep_alpha: qep,
            ..Default::default()
        })
        .run(&model, &calib)
        .unwrap();
        // Exclude shared stream propagation: Table 3 measures the
        // quantization process itself.
        out.report.hessian_s() + out.report.quant_s() + out.report.correction_s()
    };
    // Average a few runs to de-noise on a busy core.
    let avg = |method: Method, qep: Option<f32>| {
        (0..3).map(|_| time_of(method, qep)).sum::<f64>() / 3.0
    };
    let t_gptq = avg(Method::Gptq, None);
    let t_awq = avg(Method::Awq, None);
    let t_qep_rtn = avg(Method::Rtn, Some(0.5));
    eprintln!("[table3] gptq={t_gptq:.3}s awq={t_awq:.3}s qep+rtn={t_qep_rtn:.3}s");
    // Robust part of the paper's ordering at this scale: QEP+RTN < AWQ.
    // (Our Rust GPTQ column loop is disproportionately fast relative to
    // the paper's GPU implementation at d=64; the GPTQ/QEP+RTN crossover
    // is scale-dependent — see EXPERIMENTS.md Table 3 notes.)
    // Strict ordering only holds for optimized builds — debug-build cost
    // ratios are dominated by unoptimized f64 scalar loops, so there we
    // only sanity-check the magnitudes.
    if cfg!(debug_assertions) {
        assert!(
            t_qep_rtn < t_awq * 1.5 && t_qep_rtn < t_gptq * 4.0,
            "debug-build sanity: qep+rtn {t_qep_rtn:.3}s vs awq {t_awq:.3}s gptq {t_gptq:.3}s"
        );
        return;
    }
    assert!(
        t_qep_rtn < t_awq,
        "QEP+RTN ({t_qep_rtn:.3}s) should beat AWQ ({t_awq:.3}s)"
    );
    assert!(
        t_qep_rtn < t_gptq * 4.0,
        "QEP+RTN ({t_qep_rtn:.3}s) wildly slower than GPTQ ({t_gptq:.3}s)"
    );
}

#[test]
fn group_wise_int2_rescues_rtn() {
    // Appendix trend: INT2 per-channel collapses; INT2g32 is far better.
    let (model, trained) = subject();
    if !trained {
        eprintln!("[group_wise_int2] SKIP quality assertion on random weights");
    }
    let calib = calib(&model);
    let eval = eval_tokens(&model);
    let run = |quant: QuantConfig| {
        let out = Pipeline::new(PipelineConfig {
            quant,
            method: Method::Rtn,
            qep_alpha: Some(0.5),
            ..Default::default()
        })
        .run(&model, &calib)
        .unwrap();
        perplexity(&out.model, &eval)
    };
    let pc = run(QuantConfig::int(2));
    let g32 = run(QuantConfig::int_group(2, 32));
    eprintln!("[int2] per-channel={pc:.1} g32={g32:.1}");
    if trained {
        assert!(g32 < pc, "g32 {g32} !< per-channel {pc}");
    }
}

#[test]
fn all_methods_preserve_ppl_at_int8() {
    // 8-bit should be near-lossless for every method — a regression guard
    // for quantizer bugs that the low-bit chaos could mask.
    let (model, _) = subject();
    let calib = calib(&model);
    let eval = eval_tokens(&model);
    let base_ppl = perplexity(&model, &eval);
    for method in Method::all() {
        let out = Pipeline::new(PipelineConfig {
            quant: QuantConfig::int(8),
            method,
            qep_alpha: Some(0.5),
            ..Default::default()
        })
        .run(&model, &calib)
        .unwrap();
        let ppl = perplexity(&out.model, &eval);
        assert!(
            (ppl - base_ppl).abs() / base_ppl < 0.05,
            "{method:?} INT8 ppl {ppl} vs fp {base_ppl}"
        );
    }
}

/// A model with enough blocks for a CBQ window to start past block 0
/// (windows anchored at the entry are provable no-ops), plus a small
/// calibration stream.
fn cbq_subject(n_blocks: usize) -> (Model, Vec<u32>) {
    let mut cfg = ModelConfig::new("unit", 16, n_blocks, 2, 32);
    cfg.seq_len = 8;
    let model = Model::random(&cfg, 5);
    let mut rng = Rng::new(6);
    let calib: Vec<u32> = (0..8 * 16).map(|_| rng.below(256) as u32).collect();
    (model, calib)
}

fn cbq_run(
    model: &Model,
    calib: &[u32],
    method: Method,
    qep_alpha: Option<f32>,
    cbq_window: usize,
    max_blocks: Option<usize>,
) -> Model {
    Pipeline::new(PipelineConfig {
        quant: QuantConfig::int(3),
        method,
        qep_alpha,
        cbq_window,
        max_blocks,
        ..Default::default()
    })
    .run(model, calib)
    .unwrap()
    .model
}

fn qtz_bytes(m: &Model, tag: &str) -> Vec<u8> {
    let p = std::env::temp_dir().join(format!("qep_cbq_{tag}_{}.qtz", std::process::id()));
    m.save(&p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    std::fs::remove_file(&p).ok();
    bytes
}

#[test]
fn cbq_window_one_and_base_gptq_windows_match_layer_wise_bytes() {
    let (model, calib) = cbq_subject(4);
    // `cbq_window: 1` IS the pre-CBQ layer-wise pipeline, byte for byte.
    let layer_wise = qtz_bytes(&cbq_run(&model, &calib, Method::Gptq, Some(0.5), 1, None), "lw");
    let default_cfg = Pipeline::new(PipelineConfig {
        quant: QuantConfig::int(3),
        method: Method::Gptq,
        qep_alpha: Some(0.5),
        ..Default::default()
    })
    .run(&model, &calib)
    .unwrap()
    .model;
    assert_eq!(layer_wise, qtz_bytes(&default_cfg, "default"));
    // Base GPTQ never reads the full-precision reference stream, so the
    // windowed refinement is a bitwise no-op for it at EVERY window — an
    // anchor that pins the refinement pass to the pass-1 inputs.
    let base_w1 = qtz_bytes(&cbq_run(&model, &calib, Method::Gptq, None, 1, None), "g1");
    for w in [2usize, 3, 4] {
        let got = qtz_bytes(&cbq_run(&model, &calib, Method::Gptq, None, w, None), "gw");
        assert_eq!(got, base_w1, "base GPTQ must be invariant at cbq window {w}");
    }
}

#[test]
fn cbq_window_beyond_block_count_clamps_to_layer_wise_bytes() {
    // Windows larger than the quantized block count clamp (loudly) to
    // one whole-model window — which starts at block 0, where the
    // quantized and full-precision entry streams coincide, so the
    // result is provably the layer-wise bytes.
    let (model, calib) = cbq_subject(4);
    let w1 = qtz_bytes(&cbq_run(&model, &calib, Method::Gptq, Some(0.5), 1, None), "c1");
    for w in [4usize, 10, 999] {
        let got = qtz_bytes(&cbq_run(&model, &calib, Method::Gptq, Some(0.5), w, None), "cw");
        assert_eq!(got, w1, "cbq window {w} on a 4-block model must clamp to layer-wise");
    }
}

#[test]
fn cbq_composes_with_max_blocks() {
    // Quantizing a 6-block model with max_blocks=4: the window schedule
    // sees 4 quantized blocks, refines the [2, 4) window, and leaves the
    // full-precision suffix untouched.
    let (model, calib) = cbq_subject(6);
    let lw = cbq_run(&model, &calib, Method::Gptq, Some(0.5), 1, Some(4));
    let cb = cbq_run(&model, &calib, Method::Gptq, Some(0.5), 2, Some(4));
    // Blocks ahead of the refining window match the layer-wise run...
    for b in [0usize, 1] {
        for name in BlockWeights::LINEAR_NAMES {
            assert_eq!(lw.blocks[b].linear(name), cb.blocks[b].linear(name), "block {b} {name}");
        }
    }
    // ...the unquantized suffix is the original model in both runs...
    for b in [4usize, 5] {
        for name in BlockWeights::LINEAR_NAMES {
            assert_eq!(cb.blocks[b].linear(name), model.blocks[b].linear(name), "block {b} {name}");
        }
    }
    // ...and the [2, 4) window genuinely re-reconstructed (QEP's δ
    // correction sees the window-local reference, not the global one).
    let refined_differs = (2usize..4).any(|b| {
        BlockWeights::LINEAR_NAMES
            .iter()
            .any(|name| lw.blocks[b].linear(name) != cb.blocks[b].linear(name))
    });
    assert!(refined_differs, "cbq window [2, 4) under max_blocks=4 never changed a weight");
}

#[test]
fn pipeline_handles_single_segment_calibration() {
    // Degenerate calibration budgets must not crash (m < d makes Ĥ rank
    // deficient — damping keeps it invertible).
    let mut cfg = ModelConfig::new("unit", 16, 2, 2, 32);
    cfg.seq_len = 8;
    let model = Model::random(&cfg, 3);
    let mut rng = Rng::new(4);
    let calib: Vec<u32> = (0..8).map(|_| rng.below(256) as u32).collect();
    let out = Pipeline::new(PipelineConfig {
        quant: QuantConfig::int(4),
        method: Method::Gptq,
        qep_alpha: Some(1.0),
        ..Default::default()
    })
    .run(&model, &calib)
    .unwrap();
    out.model.validate().unwrap();
}
