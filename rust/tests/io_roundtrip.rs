//! Persistence round-trip gates for the two on-disk formats:
//!
//! * `.qtz` tensor containers (`io::qtz`): write → read → write must be
//!   **byte-identical** — the format is the boundary every quantized
//!   model and the parallel-equivalence gates compare across.
//! * `CellRecord` JSON lines (`io::results`): every `f64` — including
//!   non-finite and subnormal values — must survive bit-exactly, torn
//!   tails (SIGKILL mid-append) must be recoverable, and the `--resume`
//!   validation must reject records that do not belong to the manifest.

use qep::exp::common::{scan_record_dir, status_report, validate_resume};
use qep::exp::plan::{manifest, verify_coverage, PlanParams, SweepId};
use qep::io::results::{
    read_records, read_records_tolerant, truncate_torn, write_records, CellRecord,
    RecordAppender,
};
use qep::io::TensorFile;
use qep::linalg::Mat;
use qep::model::Size;
use qep::util::json::Json;
use qep::util::rng::Rng;
use std::path::PathBuf;

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("qep_io_rt_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn qtz_write_read_write_is_byte_identical() {
    let mut rng = Rng::new(1);
    let mut tf = TensorFile::new();
    tf.meta.set("model", Json::Str("tiny-s".into()));
    tf.meta.set("bits", Json::Num(3.0));
    tf.put_mat("blocks.0.attn.wq", &Mat::randn(16, 16, 1.0, &mut rng));
    tf.put_i8("blocks.0.attn.wq.codes", &[16, 16], &vec![-8i8; 256]);
    tf.put_f32("blocks.0.attn.wq.scales", &[16], &vec![0.125f32; 16]);
    // Awkward f32 payloads: subnormal, max, negative zero, tiny.
    tf.put_f32(
        "edge",
        &[4],
        &[f32::MIN_POSITIVE, f32::MAX, -0.0f32, 1.0e-45],
    );

    let first = tf.serialize();
    let back = TensorFile::deserialize(&first).unwrap();
    let second = back.serialize();
    assert_eq!(first, second, "qtz write→read→write must reproduce the bytes");

    // Same through the filesystem.
    let dir = tmp("qtz");
    let path = dir.join("model.qtz");
    tf.save(&path).unwrap();
    let loaded = TensorFile::load(&path).unwrap();
    assert_eq!(loaded.serialize(), first);
    // The f32 payload is bit-exact, -0.0 and subnormals included.
    let (_, edge) = loaded.get_f32("edge").unwrap();
    let want = [f32::MIN_POSITIVE, f32::MAX, -0.0f32, 1.0e-45];
    for (a, b) in edge.iter().zip(want.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "f32 payload drifted");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn record_round_trip_preserves_non_finite_and_subnormal_f64s() {
    let subnormal_min = f64::from_bits(1); // 5e-324, the smallest subnormal
    let almost_normal = f64::from_bits(0x000F_FFFF_FFFF_FFFF); // largest subnormal
    let values = [
        f64::INFINITY,
        f64::NEG_INFINITY,
        subnormal_min,
        almost_normal,
        f64::MIN_POSITIVE,
        f64::MAX,
        1.0 / 3.0,
    ];
    let mut rec = CellRecord::new("fig3/INT3/tiny-s/base/s0".into(), 1, 2);
    rec.ppl = values.iter().enumerate().map(|(i, &v)| (format!("m{i}"), v)).collect();
    rec.deltas = values.to_vec();
    rec.deltas.push(f64::NAN);
    rec.normalize();

    let line = rec.to_line();
    assert!(line.ends_with('\n'), "lines are newline-terminated");
    let back = CellRecord::from_json(&Json::parse(line.trim_end()).unwrap()).unwrap();
    for ((k, a), (_, b)) in rec.ppl.iter().zip(back.ppl.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "ppl[{k}] drifted");
    }
    for (i, (a, b)) in rec.deltas.iter().zip(back.deltas.iter()).enumerate() {
        if a.is_nan() {
            assert!(b.is_nan(), "deltas[{i}]: NaN lost");
        } else {
            assert_eq!(a.to_bits(), b.to_bits(), "deltas[{i}] drifted");
        }
    }
}

#[test]
fn torn_tail_recovery_through_appender_and_scan() {
    let dir = tmp("torn");
    let path = dir.join("fig2.shard-1-of-2.jsonl");
    let a = CellRecord::new("fig2/tiny-s/INT3/b1/base".into(), 1, 2);
    let b = CellRecord::new("fig2/tiny-s/INT3/b1/+qep".into(), 1, 2);
    {
        let mut app = RecordAppender::open(&path).unwrap();
        app.append(&a).unwrap();
        app.append(&b).unwrap();
    }
    let clean_bytes = std::fs::read(&path).unwrap();

    // Simulate a SIGKILL mid-append of a third record: a partial line
    // with no terminating newline.
    let mut torn_bytes = clean_bytes.clone();
    torn_bytes.extend_from_slice(br#"{"id":"fig2/tiny-s/INT"#);
    std::fs::write(&path, &torn_bytes).unwrap();

    // Tolerant readers drop exactly the fragment.
    let out = read_records_tolerant(&path).unwrap();
    assert_eq!(out.records.len(), 2);
    assert_eq!(out.torn.as_ref().unwrap().valid_bytes as usize, clean_bytes.len());
    assert_eq!(read_records(&path).unwrap(), vec![a.clone(), b.clone()]);

    // The directory scan reports the torn file; truncation restores the
    // clean prefix byte-for-byte and the scan comes back clean.
    let scan = scan_record_dir(&dir).unwrap();
    assert_eq!(scan.files.len(), 1);
    assert_eq!(scan.records.len(), 2);
    assert_eq!(scan.torn.len(), 1);
    assert!(truncate_torn(&path).unwrap());
    assert_eq!(std::fs::read(&path).unwrap(), clean_bytes);
    let scan = scan_record_dir(&dir).unwrap();
    assert!(scan.torn.is_empty());

    // A *terminated* garbage line is corruption, not a torn tail: hard
    // error even for the tolerant reader.
    std::fs::write(&path, b"not json at all\n").unwrap();
    assert!(read_records_tolerant(&path).is_err());

    std::fs::remove_dir_all(&dir).ok();
}

/// Build the 2-cell Fig. 2 manifest and a matching record per cell.
fn fig2_manifest_and_records() -> (Vec<qep::exp::PlanCell>, Vec<CellRecord>) {
    let params = PlanParams::for_sizes(&[Size::TinyS]);
    let cells = manifest(SweepId::Fig2, &params).unwrap();
    assert_eq!(cells.len(), 2);
    let recs = cells.iter().map(|c| CellRecord::new(c.id(), 1, 1)).collect();
    (cells, recs)
}

#[test]
fn resume_validation_rejects_foreign_duplicate_and_malformed_records() {
    let (cells, recs) = fig2_manifest_and_records();
    let dir = tmp("resume_validate");

    // A complete, matching directory validates to the full skip set.
    write_records(&dir.join("fig2.shard-1-of-1.jsonl"), &recs).unwrap();
    let scan = scan_record_dir(&dir).unwrap();
    let done = validate_resume(&cells, &scan).unwrap();
    assert_eq!(done.len(), 2);
    assert!(done.contains(&cells[0].id()));

    // Parameter mismatch: a *valid* cell id from a different sweep/flags
    // is a hard error that says so.
    let foreign = CellRecord::new("table12/INT3/GPTQ/+qep/tiny-s".into(), 1, 1);
    write_records(&dir.join("stray.jsonl"), &[foreign]).unwrap();
    let err = validate_resume(&cells, &scan_record_dir(&dir).unwrap())
        .unwrap_err()
        .to_string();
    assert!(err.contains("not in this manifest"), "{err}");
    assert!(err.contains("parameter mismatch"), "{err}");
    std::fs::remove_file(dir.join("stray.jsonl")).unwrap();

    // Malformed id: also a hard error, different diagnosis.
    let junk = CellRecord::new("bogus/nonsense".into(), 1, 1);
    write_records(&dir.join("junk.jsonl"), &[junk]).unwrap();
    let err = validate_resume(&cells, &scan_record_dir(&dir).unwrap())
        .unwrap_err()
        .to_string();
    assert!(err.contains("not a well-formed cell id"), "{err}");
    std::fs::remove_file(dir.join("junk.jsonl")).unwrap();

    // Duplicate records across files: hard error naming the cell.
    write_records(&dir.join("dupe.jsonl"), &recs[..1].to_vec()).unwrap();
    let err = validate_resume(&cells, &scan_record_dir(&dir).unwrap())
        .unwrap_err()
        .to_string();
    assert!(err.contains("duplicate records"), "{err}");
    assert!(err.contains(&cells[0].id()), "{err}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn status_report_agrees_with_verify_coverage() {
    let (cells, recs) = fig2_manifest_and_records();
    let dir = tmp("status");

    // Empty directory: everything missing, nothing clean.
    let report = status_report(&cells, &scan_record_dir(&dir).unwrap());
    assert_eq!((report.done, report.total), (0, 2));
    assert_eq!(report.missing.len(), 2);
    assert!(!report.clean());

    // Half done: the missing id is named, coverage still fails.
    write_records(&dir.join("fig2.shard-1-of-2.jsonl"), &recs[..1].to_vec()).unwrap();
    let scan = scan_record_dir(&dir).unwrap();
    let report = status_report(&cells, &scan);
    assert_eq!((report.done, report.total), (1, 2));
    assert_eq!(report.missing, vec![cells[1].id()]);
    assert!(!report.clean());
    let coverage =
        verify_coverage(&cells, scan.records.into_iter().map(|(_, r)| r).collect::<Vec<_>>());
    assert!(coverage.is_err(), "status says missing ⇒ coverage must fail");

    // Complete: clean() ⇔ verify_coverage succeeds.
    write_records(&dir.join("fig2.shard-2-of-2.jsonl"), &recs[1..].to_vec()).unwrap();
    let scan = scan_record_dir(&dir).unwrap();
    let report = status_report(&cells, &scan);
    assert_eq!((report.done, report.total), (2, 2));
    assert!(report.clean());
    let rendered = report.render("'fig2'");
    assert!(rendered.contains("2/2 cell(s) done"), "{rendered}");
    assert!(rendered.contains("ready to `repro exp merge`"), "{rendered}");
    verify_coverage(&cells, scan.records.into_iter().map(|(_, r)| r).collect::<Vec<_>>())
        .expect("status says clean ⇒ coverage must pass");

    // A duplicate flips both: status reports it, coverage rejects it.
    write_records(&dir.join("dupe.jsonl"), &recs[..1].to_vec()).unwrap();
    let scan = scan_record_dir(&dir).unwrap();
    let report = status_report(&cells, &scan);
    assert_eq!(report.duplicates, vec![cells[0].id()]);
    assert!(!report.clean());
    assert!(verify_coverage(
        &cells,
        scan.records.into_iter().map(|(_, r)| r).collect::<Vec<_>>()
    )
    .is_err());

    std::fs::remove_dir_all(&dir).ok();
}
