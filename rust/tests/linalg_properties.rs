//! Property tests for the GEMM kernels: the dispatching entry points and
//! the pooled kernels must match a naive triple-loop reference on random
//! rectangular shapes, survive degenerate (empty / 1×n / n×1) shapes, and
//! stay bit-identical to the serial kernels for every thread count.

use qep::linalg::{
    matmul, matmul_nt, matmul_nt_serial, matmul_nt_with, matmul_serial, matmul_tn,
    matmul_tn_serial, matmul_tn_with, matmul_with, Mat,
};
use qep::util::pool::Pool;
use qep::util::rng::Rng;

/// f64-accumulated reference C = A·B.
fn naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let mut c = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut s = 0.0f64;
            for k in 0..a.cols {
                s += a.at(i, k) as f64 * b.at(k, j) as f64;
            }
            *c.at_mut(i, j) = s as f32;
        }
    }
    c
}

fn assert_close(a: &Mat, b: &Mat, tol: f32, label: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{label}: shape");
    for (x, y) in a.data.iter().zip(b.data.iter()) {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{label}: {x} vs {y}"
        );
    }
}

/// Random rectangular shapes plus every degenerate axis combination.
const SHAPES: [(usize, usize, usize); 14] = [
    (1, 1, 1),
    (1, 64, 1),
    (1, 17, 9),
    (9, 17, 1),
    (7, 1, 5),
    (8, 8, 8),
    (33, 129, 65),
    (64, 300, 48),
    (128, 64, 256),
    (0, 5, 3),
    (5, 0, 3),
    (5, 3, 0),
    (0, 0, 0),
    (2, 512, 512),
];

#[test]
fn matmul_matches_naive_on_all_shapes() {
    let mut rng = Rng::new(1);
    for (m, k, n) in SHAPES {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let want = naive(&a, &b);
        assert_close(&matmul(&a, &b), &want, 1e-4, &format!("matmul {m}x{k}x{n}"));
        assert_close(
            &matmul_serial(&a, &b),
            &want,
            1e-4,
            &format!("matmul_serial {m}x{k}x{n}"),
        );
    }
}

#[test]
fn matmul_nt_matches_naive_on_all_shapes() {
    let mut rng = Rng::new(2);
    for (m, k, n) in SHAPES {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(n, k, 1.0, &mut rng); // [n, k]: rows dotted with rows
        let want = naive(&a, &b.transpose());
        assert_close(&matmul_nt(&a, &b), &want, 1e-4, &format!("matmul_nt {m}x{k}x{n}"));
        assert_close(
            &matmul_nt_serial(&a, &b),
            &want,
            1e-4,
            &format!("matmul_nt_serial {m}x{k}x{n}"),
        );
    }
}

#[test]
fn matmul_tn_matches_naive_on_all_shapes() {
    let mut rng = Rng::new(3);
    for (m, k, n) in SHAPES {
        let a = Mat::randn(k, m, 1.0, &mut rng); // [k, m]: transposed operand
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let want = naive(&a.transpose(), &b);
        assert_close(&matmul_tn(&a, &b), &want, 1e-4, &format!("matmul_tn {m}x{k}x{n}"));
        assert_close(
            &matmul_tn_serial(&a, &b),
            &want,
            1e-4,
            &format!("matmul_tn_serial {m}x{k}x{n}"),
        );
    }
}

#[test]
fn pooled_kernels_are_bit_identical_to_serial_on_all_shapes() {
    let mut rng = Rng::new(4);
    for (m, k, n) in SHAPES {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let bt = Mat::randn(n, k, 1.0, &mut rng);
        let at = Mat::randn(k, m, 1.0, &mut rng);
        let want = matmul_serial(&a, &b);
        let want_nt = matmul_nt_serial(&a, &bt);
        let want_tn = matmul_tn_serial(&at, &b);
        for threads in [2usize, 3, 4, 8] {
            let pool = Pool::new(threads);
            assert_eq!(
                matmul_with(&a, &b, &pool),
                want,
                "matmul {m}x{k}x{n} t={threads}"
            );
            assert_eq!(
                matmul_nt_with(&a, &bt, &pool),
                want_nt,
                "matmul_nt {m}x{k}x{n} t={threads}"
            );
            assert_eq!(
                matmul_tn_with(&at, &b, &pool),
                want_tn,
                "matmul_tn {m}x{k}x{n} t={threads}"
            );
        }
    }
}

#[test]
fn hessian_build_is_exactly_symmetric_under_the_pool() {
    // XᵀX: element (i,j) and (j,i) accumulate the same products in the
    // same k order on (possibly) different workers; IEEE multiplication
    // commutes, so the result must be exactly symmetric — a direct probe
    // of the fixed-reduction-order guarantee.
    let mut rng = Rng::new(5);
    for (tokens, d) in [(300, 33), (1024, 96)] {
        let x = Mat::randn(tokens, d, 1.0, &mut rng);
        for threads in [1usize, 4] {
            let h = matmul_tn_with(&x, &x, &Pool::new(threads));
            assert_eq!((h.rows, h.cols), (d, d));
            for i in 0..d {
                assert!(h.at(i, i) >= 0.0, "diag ({i},{i}) negative");
                for j in 0..i {
                    assert_eq!(h.at(i, j), h.at(j, i), "asymmetry at ({i},{j}) t={threads}");
                }
            }
        }
    }
}

#[test]
fn zero_inputs_give_exactly_zero_outputs() {
    let pool = Pool::new(4);
    let a = Mat::zeros(100, 200);
    let b = Mat::zeros(200, 50);
    for v in matmul_with(&a, &b, &pool).data {
        assert_eq!(v, 0.0);
    }
}
